"""Compiled experiment engine: whole AFL runs as one XLA program, scaled
across policy x mobility x speed x seed grids (see README.md here)."""
from repro.experiments.batch import run_seed_batch
from repro.experiments.grid import ExperimentGrid, GridCell
from repro.experiments.results import ResultsStore, mean_ci
from repro.experiments.scan_engine import (
    DataShard,
    make_run_fn,
    prestack_batches,
    run_afl_scanned,
)

__all__ = [
    "DataShard",
    "ExperimentGrid",
    "GridCell",
    "ResultsStore",
    "make_run_fn",
    "mean_ci",
    "prestack_batches",
    "run_afl_scanned",
    "run_seed_batch",
]
