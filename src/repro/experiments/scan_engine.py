"""Whole-run AFL lowering: the round loop folded into ``lax.scan``.

``core/runner.py::run_afl`` dispatches one jitted ``afl_round`` per round
from Python, re-hosting minibatches and scenario rows every round.  Here the
entire R-round run is ONE compiled XLA program:

* the scenario schedule (rounds x N zeta/tau/h2 from
  ``ScenarioProvider.schedule()``) lives on device and is consumed as scan
  inputs;
* minibatches are sampled *inside* the scan from a device-resident
  ``DataShard`` (``fold_in(key, r)`` so round r's batch is a pure function
  of the key), or gathered from a prestacked (rounds, N, B, ...) tensor
  when exact ``DeviceLoader`` parity is required;
* periodic eval is buffered: the scan is segmented at the eval rounds, and
  each segment boundary computes the eval metric and the windowed
  aggregates (uploads, k_mean, theta_mean, power_mean) from carried totals
  — the history comes back as (num_evals,) device arrays, fetched once.

``run_afl_scanned`` is metric-equivalent to the loop runner on the same
seeds (tests/test_experiments.py) and is the unit the grid engine
(``batch.py``) vmaps over seeds.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as BL
from repro.core.afl import afl_init, afl_round
from repro.core.runner import (
    RunResult,
    build_provider,
    make_eval_fn,
    resolve_telemetry,
    sample_budgets,
)
from repro.telemetry import HIST_KEYS, record_het, record_round
from repro.utils import get_logger

log = get_logger("repro.scan_engine")


# ---------------------------------------------------------------------------
# Batch sources
# ---------------------------------------------------------------------------


class DataShard:
    """Device-resident federation data with in-scan minibatch sampling.

    Per-device arrays are wrap-padded to a rectangular (N, M, ...) block and
    pushed to device ONCE; round r's stacked (N, B, ...) minibatch is
    ``fold_in(key, r)`` + a per-device gather, so sampling is traceable and
    runs inside the scan (and identically outside it — the loop runner
    calls ``round_batch(r)`` for engine-equivalence tests).

    Sampling is uniform-with-replacement over each device's true row count
    (padding rows are never drawn), unlike ``DeviceLoader``'s
    epoch-permutation semantics — both are unbiased samplers of D_n.
    """

    def __init__(self, device_arrays: list[dict], batch_size: int,
                 seed: int = 0):
        counts = np.array(
            [len(next(iter(d.values()))) for d in device_arrays], np.int32
        )
        m = int(counts.max())
        self.data = {
            k: jnp.asarray(np.stack([
                np.resize(d[k], (m,) + d[k].shape[1:]) for d in device_arrays
            ]))
            for k in device_arrays[0]
        }
        self.counts = jnp.asarray(counts)
        self.num_devices = len(device_arrays)
        self.batch_size = batch_size
        self.key = jax.random.key(seed)

    def __len__(self):
        return self.num_devices

    def seed_key(self, seed: int):
        """Independent sampling stream for one grid seed."""
        return jax.random.fold_in(self.key, seed)

    def traced_batch(self, key, r):
        """(N, B, ...) minibatch for round r — jnp-traceable."""
        kr = jax.random.fold_in(key, r)
        idx = jax.random.randint(
            kr, (self.num_devices, self.batch_size), 0, self.counts[:, None]
        )
        return jax.tree.map(
            lambda a: jax.vmap(lambda rows, ii: rows[ii])(a, idx), self.data
        )



def prestack_batches(loader, rounds: int):
    """Materialise ``rounds`` DeviceLoader draws as (rounds, N, B, ...) device
    arrays — exact loader parity for scanned-vs-loop equivalence."""
    rows = [loader.sample_all() for _ in range(rounds)]
    return {
        k: jnp.asarray(np.stack([row[k] for row in rows])) for k in rows[0]
    }


def _prestacked_sampler(ctx, r):
    return jax.tree.map(lambda v: v[r], ctx)


# ---------------------------------------------------------------------------
# The compiled run
# ---------------------------------------------------------------------------


def eval_points(rounds: int, eval_every: int) -> list[int]:
    """1-based round indices at which the loop runner evaluates."""
    pts = [r for r in range(eval_every, rounds + 1, eval_every)]
    if not pts or pts[-1] != rounds:
        pts.append(rounds)
    return pts


def make_run_fn(model, cfg, fl, policy, *, rounds: int, eval_every: int,
                sampler: Callable, telemetry=None):
    """Pure function running a whole AFL experiment in one trace.

    Returns ``run(state0, zeta, tau, h2, budgets, eval_batch, sample_ctx,
    tstate0, het) -> (final_state, hist, tstate)`` where ``hist`` maps the
    loop runner's history keys (except "round") to (num_evals,) arrays.
    ``sampler(sample_ctx, r)`` yields round r's stacked minibatch:
    ``DataShard.traced_batch`` with a key context, or
    ``_prestacked_sampler`` with a (rounds, ...) tensor.

    ``het`` is the scenario's heterogeneity aux dict — (rounds, N) loss
    masks from ``ScenarioProvider.aux`` — or ``{}`` when the layer is
    disabled; it rides the scan inputs and folds into the per-device
    telemetry table each round (``record_het``).  An empty dict keeps the
    arity (and the vmap in_axes of ``batch.py``) uniform across runs.

    ``telemetry`` (a ``repro.telemetry.MetricRegistry``) threads its
    accumulation pytree ``tstate0`` through the scan carry —
    device-resident histograms/counters with no mid-run host sync.  With
    ``telemetry=None``, pass ``{}`` and the carry slot is empty.

    The function is jit- and vmap-friendly: scenario tensors, budgets, the
    initial state, the sample context, and the telemetry state batch over
    a leading seed axis; eval_batch broadcasts.
    """
    n = fl.num_devices
    eval_fn = make_eval_fn(model, cfg)
    pts = eval_points(rounds, eval_every)
    bounds = list(zip([0] + pts[:-1], pts))

    def run(state0, zeta, tau, h2, budgets, eval_batch, sample_ctx,
            tstate0, het):
        def body(carry, xs):
            state, tot, ts = carry
            r, zeta_r, tau_r, h2_r, het_r = xs
            batch = sampler(sample_ctx, r)
            state, m = afl_round(
                state, batch, zeta_r, tau_r, h2_r, budgets,
                model=model, cfg=cfg, fl=fl, policy=policy,
            )
            if telemetry is not None:
                ts = record_round(telemetry, ts, m, tau_r)
                ts = record_het(telemetry, ts, het_r if het_r else None)
            tot = {
                "uploads": tot["uploads"] + jnp.sum(m["success"]),
                "k": tot["k"] + jnp.sum(m["k"]),
                "power": tot["power"] + jnp.sum(m["power"]),
                "theta": tot["theta"] + jnp.sum(m["theta"]),
                "bits": tot["bits"] + jnp.sum(m["bits"]),
            }
            return (state, tot, ts), None

        state = state0
        ts = tstate0
        tot = {k: jnp.zeros((), jnp.float32)
               for k in ("uploads", "k", "power", "theta", "bits")}
        hist = {k: [] for k in HIST_KEYS if k != "round"}
        for start, stop in bounds:
            xs = (
                jnp.arange(start, stop, dtype=jnp.int32),
                zeta[start:stop], tau[start:stop], h2[start:stop],
                {k: v[start:stop] for k, v in het.items()},
            )
            (state, tot, ts), _ = jax.lax.scan(body, (state, tot, ts), xs)
            up = jnp.maximum(tot["uploads"], 1.0)
            hist["eval"].append(eval_fn(state.w, eval_batch))
            hist["uploads"].append(tot["uploads"])
            hist["k_mean"].append(tot["k"] / up)
            hist["energy"].append(jnp.sum(state.energy))
            hist["theta_mean"].append(tot["theta"] / (stop * n))
            hist["power_mean"].append(tot["power"] / up)
            hist["bits_mean"].append(tot["bits"] / up)
        return state, {k: jnp.stack(v) for k, v in hist.items()}, ts

    return run


@lru_cache(maxsize=16)
def _compiled_run(model, cfg, fl, policy, rounds: int, eval_every: int,
                  sampler, telemetry=None):
    """One jitted program per (model, engine-flags, shapes) group — grid
    cells that share these reuse the compilation (policy *names* are
    stripped by the grid; see ``grid.engine_policy``).  The telemetry
    registry is part of the key: runs with and without instrumentation
    are different XLA programs.

    Note: a DataShard sampler key pins that shard's device data for the
    cache entry's lifetime — bounded by the maxsize, but long-lived
    processes cycling many large datasets should prefer fresh processes
    per sweep."""
    run = make_run_fn(model, cfg, fl, policy, rounds=rounds,
                      eval_every=eval_every, sampler=sampler,
                      telemetry=telemetry)
    return jax.jit(run)


def run_afl_scanned(
    model,
    cfg,
    fl,
    policy_name: str,
    loader,
    eval_batch,
    rounds: Optional[int] = None,
    eval_every: int = 20,
    seed: Optional[int] = None,
    schedule=None,
    log_progress: bool = False,
    batch_mode: str = "auto",
    telemetry=None,
    tracer=None,
) -> RunResult:
    """Drop-in replacement for ``runner.run_afl`` running the whole
    experiment as one compiled program.

    ``batch_mode``: "shard" samples in-scan from a ``DataShard``;
    "prestack" materialises the DeviceLoader's exact draw sequence up
    front; "auto" picks by loader type.  ``telemetry`` threads a
    ``MetricRegistry`` state through the scan (fetched once at run end
    into ``RunResult.telemetry``); ``tracer`` records run/fetch spans.
    """
    rounds = rounds or fl.rounds
    seed = fl.seed if seed is None else seed
    telemetry = resolve_telemetry(fl, telemetry, s=model.num_params())
    policy = BL.ALL[policy_name](model.num_params(), fl)

    provider = build_provider(fl, policy_name, schedule, rounds, seed)
    zeta, tau, h2 = provider.schedule()
    zeta = jnp.asarray(zeta)
    tau = jnp.asarray(tau, jnp.float32)
    h2 = jnp.asarray(h2, jnp.float32)
    aux = provider.aux
    het = ({} if aux is None
           else {k: jnp.asarray(v, jnp.float32) for k, v in aux.items()})
    budgets = sample_budgets(fl, seed)

    if batch_mode == "auto":
        batch_mode = "shard" if isinstance(loader, DataShard) else "prestack"
    if batch_mode == "shard":
        sampler, sample_ctx = loader.traced_batch, loader.seed_key(seed)
    elif batch_mode == "prestack":
        sampler = _prestacked_sampler
        sample_ctx = (
            loader if isinstance(loader, dict)
            else prestack_batches(loader, rounds)
        )
    else:
        raise ValueError(f"unknown batch_mode {batch_mode!r}")

    from contextlib import nullcontext

    from repro.experiments.grid import engine_fl, engine_policy

    span = tracer.span if tracer is not None else (
        lambda name, **kw: nullcontext())
    run = _compiled_run(model, cfg, engine_fl(fl), engine_policy(policy),
                        rounds, eval_every, sampler, telemetry)
    state0 = afl_init(model, cfg, fl, jax.random.key(seed))
    eval_b = jax.device_put({k: jnp.asarray(v) for k, v in eval_batch.items()})
    tstate0 = telemetry.init_state() if telemetry is not None else {}
    with span("run"):  # first call per program traces + compiles
        state, hist_dev, tstate = run(state0, zeta, tau, h2, budgets,
                                      eval_b, sample_ctx, tstate0, het)
        if tracer is not None:
            tracer.fence(hist_dev)

    hist: dict = {"round": eval_points(rounds, eval_every)}
    with span("fetch"):
        for k, v in hist_dev.items():
            hist[k] = [float(x) for x in np.asarray(v)]
        snapshot = telemetry.fetch(tstate) if telemetry is not None else None
    if log_progress:
        for i, r in enumerate(hist["round"]):
            log.info(
                "policy=%s r=%d eval=%.4f uploads=%.0f k=%.0f E=%.0fJ",
                policy_name, r, hist["eval"][i], hist["uploads"][i],
                hist["k_mean"][i], hist["energy"][i],
            )
    return RunResult(policy_name, hist, hist["eval"][-1], state,
                     telemetry=snapshot)
