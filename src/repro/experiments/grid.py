"""Declarative experiment grids: policies x mobility x speeds x dropout x seeds.

A paper figure is a grid of AFL runs differing only in scenario knobs and
the upload policy.  ``ExperimentGrid`` enumerates the cells, derives each
cell's ``FLConfig``, and groups same-shape cells so the batch engine
(``batch.py``) vmaps the seed axis and reuses one compiled program per
(model, policy-engine-flags) group — e.g. FedAsync and FedMobile differ
only in the schedule transform, so every cell of both policies runs through
the same XLA executable.

The ``dropouts`` axis sweeps the heterogeneity layer
(``scenarios/heterogeneity``): each value becomes ``fl.het_dropout`` for
the cell, gating contact windows with client dropout.  The default
``(0.0,)`` keeps the axis collapsed — and cell slugs identical to the
pre-heterogeneity store keys, so existing result stores resolve unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field

from repro.configs import FLConfig
from repro.core import baselines as BL
from repro.core.afl import Policy


@dataclass(frozen=True)
class GridCell:
    """One experiment: a (policy, mobility, speed, dropout, seed) point."""

    policy: str
    mobility: str
    speed: float
    seed: int
    dropout: float = 0.0

    def _het_slug(self) -> str:
        # zero keeps legacy slugs stable (results stores predate the axis)
        return f"__d{self.dropout:g}" if self.dropout else ""

    @property
    def key(self) -> str:
        """Stable slug used by the results store."""
        return (f"{self.policy}__{self.mobility}__v{self.speed:g}"
                f"{self._het_slug()}__s{self.seed}")

    @property
    def group_key(self) -> str:
        """Slug of the seed-batched group this cell belongs to."""
        return (f"{self.policy}__{self.mobility}__v{self.speed:g}"
                f"{self._het_slug()}")


def engine_policy(policy: Policy) -> Policy:
    """Strip bookkeeping fields that do not change the compiled program.

    ``Policy.name`` is metadata: two policies whose numeric flags coincide
    (e.g. ``afl`` and ``fedmobile``) hash equal after stripping, so the
    scan engine's jit cache serves both from one compile.
    """
    return dataclasses.replace(policy, name="")


def engine_fl(fl: FLConfig) -> FLConfig:
    """Project an FLConfig onto the fields the compiled round reads.

    Scenario, channel, energy, and heterogeneity knobs (mobility_model,
    speed, area, bandwidth, energy_budget, het_*, scenario_backend, seed,
    ...) are consumed host-side — by ``build_provider``, ``sample_budgets``,
    and the policy/controller constructors — before anything is compiled.
    Keying the jit caches on the full config would recompile an identical
    XLA program for every speed, mobility model, and dropout level of a
    sweep; this keeps only what ``afl_round``/``afl_init``/``make_run_fn``
    actually consume.
    """
    return FLConfig(
        num_devices=fl.num_devices,
        rounds=fl.rounds,
        learning_rate=fl.learning_rate,
        batch_size=fl.batch_size,
        sparsifier=fl.sparsifier,
        sample_size=fl.sample_size,
    )


@dataclass(frozen=True)
class ExperimentGrid:
    """The sweep specification behind a paper-style comparison table."""

    policies: tuple = ("mads",)
    mobility_models: tuple = ("exponential",)
    speeds: tuple = (0.0,)
    seeds: tuple = (0,)
    dropouts: tuple = (0.0,)  # heterogeneity axis: fl.het_dropout per cell
    rounds: int = 200
    eval_every: int = 20
    base: FLConfig = field(default_factory=FLConfig)

    def __post_init__(self):
        unknown = [p for p in self.policies if p not in BL.ALL]
        if unknown:
            raise KeyError(f"unknown policies {unknown}; known: "
                           f"{sorted(BL.ALL)}")

    def cells(self) -> list[GridCell]:
        return [
            GridCell(p, m, float(v), int(s), float(d))
            for p, m, v, d, s in itertools.product(
                self.policies, self.mobility_models, self.speeds,
                self.dropouts, self.seeds
            )
        ]

    def groups(self) -> list[tuple[str, str, float, float, list[GridCell]]]:
        """Cells bucketed by (policy, mobility, speed, dropout) — the seed
        axis of each bucket is what ``batch.run_seed_batch`` vmaps."""
        out = []
        for p, m, v, d in itertools.product(
            self.policies, self.mobility_models, self.speeds, self.dropouts
        ):
            out.append((p, m, float(v), float(d),
                        [GridCell(p, m, float(v), int(s), float(d))
                         for s in self.seeds]))
        return out

    def fl_for(self, mobility: str, speed: float,
               dropout: float = 0.0) -> FLConfig:
        """The cell's FLConfig: the base config with scenario knobs set."""
        return dataclasses.replace(
            self.base, mobility_model=mobility, speed=float(speed),
            het_dropout=float(dropout), rounds=self.rounds,
        )

    def size(self) -> int:
        return (len(self.policies) * len(self.mobility_models)
                * len(self.speeds) * len(self.dropouts) * len(self.seeds))
