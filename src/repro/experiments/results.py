"""Resumable results store for experiment grids.

One directory per sweep: each completed cell (policy, mobility, speed,
seed) lands as ``cells/<key>.npz`` (the full metric history) and one JSON
line in ``results.jsonl`` (metadata + final eval — the build artifact CI
uploads).  A sweep restarted over the same directory skips completed cells,
so a killed 300-cell grid resumes where it stopped.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Iterable, Optional

import numpy as np

from repro.experiments.grid import ExperimentGrid, GridCell
from repro.telemetry import HIST_KEYS as _HIST_KEYS


def mean_ci(values, confidence: float = 0.95) -> tuple[float, float]:
    """Mean and normal-approximation confidence half-width across seeds."""
    v = np.asarray(list(values), np.float64)
    if v.size == 0:
        return float("nan"), float("nan")
    if v.size == 1:
        return float(v[0]), 0.0
    z = {0.90: 1.645, 0.95: 1.960, 0.99: 2.576}.get(round(confidence, 2), 1.960)
    return float(v.mean()), float(z * v.std(ddof=1) / math.sqrt(v.size))


class ResultsStore:
    """npz-per-cell + JSONL index under one sweep directory."""

    def __init__(self, root: str):
        self.root = root
        self.cell_dir = os.path.join(root, "cells")
        self.index_path = os.path.join(root, "results.jsonl")
        os.makedirs(self.cell_dir, exist_ok=True)

    # -- cell lifecycle -----------------------------------------------------

    def _cell_path(self, cell: GridCell) -> str:
        return os.path.join(self.cell_dir, cell.key + ".npz")

    def done(self, cell: GridCell) -> bool:
        return os.path.exists(self._cell_path(cell))

    def pending(self, cells: Iterable[GridCell]) -> list[GridCell]:
        return [c for c in cells if not self.done(c)]

    def save(self, cell: GridCell, history: dict,
             meta: Optional[dict] = None) -> None:
        arrays = {k: np.asarray(history[k]) for k in _HIST_KEYS
                  if k in history}
        # write-then-rename: a kill mid-save must not leave a truncated npz
        # that done() would treat as a completed cell on resume
        path = self._cell_path(cell)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:  # file object: savez won't append ".npz"
            np.savez(f, **arrays)
        os.replace(tmp, path)
        rec = dict(dataclasses.asdict(cell), cell=cell.key,
                   final_eval=float(history["eval"][-1]),
                   uploads=float(history["uploads"][-1]))
        if meta:
            rec.update(meta)
        with open(self.index_path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    def load(self, cell: GridCell) -> dict:
        with np.load(self._cell_path(cell)) as z:
            return {k: z[k].tolist() for k in z.files}

    # -- aggregation --------------------------------------------------------

    def aggregate(self, grid: ExperimentGrid, metric: str = "eval") -> dict:
        """mean±CI of the final ``metric`` across seeds, per grid group.

        Returns ``{(policy, mobility, speed, dropout): (mean, ci,
        n_seeds)}`` over the groups whose cells are (at least partially)
        complete.
        """
        out = {}
        for policy, mobility, speed, dropout, cells in grid.groups():
            finals = [self.load(c)[metric][-1] for c in cells if self.done(c)]
            if finals:
                m, ci = mean_ci(finals)
                out[(policy, mobility, speed, dropout)] = (m, ci, len(finals))
        return out

    def table(self, grid: ExperimentGrid, metric: str = "eval") -> str:
        """Paper-style comparison table: policy rows x (mobility, speed[,
        dropout]) columns of final-metric mean±CI.  The dropout suffix only
        appears when the grid actually sweeps the heterogeneity axis."""
        agg = self.aggregate(grid, metric)
        dropouts = getattr(grid, "dropouts", (0.0,))
        cols = [(m, v, d) for m in grid.mobility_models
                for v in grid.speeds for d in dropouts]
        head = f"{'policy':>12s}"
        for m, v, d in cols:
            label = m[:10] + "@v" + format(v, "g")
            if len(dropouts) > 1 or d:
                label += "@d" + format(d, "g")
            head += f" {label:>18s}"
        lines = [head]
        for p in grid.policies:
            row = f"{p:>12s}"
            for m, v, d in cols:
                cell = agg.get((p, m, float(v), float(d)))
                row += (f" {cell[0]:>10.4f}±{cell[1]:<6.4f}"
                        if cell else f" {'—':>18s}")
            lines.append(row)
        return "\n".join(lines)
