"""Seed-axis vmapping + mesh sharding of the compiled AFL run.

One grid group = one (policy, mobility, speed) point replicated over S
seeds.  Everything that varies per seed — scenario tensors, budgets, the
initial federation state, the minibatch-sampling key — is stacked on a
leading seed axis and the whole-run function from ``scan_engine.make_run_fn``
is vmapped over it: S runs execute as ONE program with batched linear
algebra instead of S sequential loops.  On a multi-device mesh the seed
axis is sharded (``launch.mesh.make_seed_mesh``) so seeds spread across
chips; on one CPU the vmap alone already amortises dispatch overhead.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import baselines as BL
from repro.core.afl import afl_init
from repro.core.runner import RunResult, build_provider, sample_budgets
from repro.experiments.grid import engine_fl, engine_policy
from repro.experiments.scan_engine import eval_points, make_run_fn
from repro.utils import get_logger

log = get_logger("repro.batch")


@lru_cache(maxsize=16)
def _compiled_vrun(model, cfg, fl, policy, rounds: int, eval_every: int,
                   sampler, telemetry=None):
    """vmapped whole-run program, cached per (model, engine-flags) group."""
    run = make_run_fn(model, cfg, fl, policy, rounds=rounds,
                      eval_every=eval_every, sampler=sampler,
                      telemetry=telemetry)
    # batched: state0, zeta, tau, h2, budgets, sample_ctx, telemetry state,
    # heterogeneity aux masks; shared: eval_batch
    return jax.jit(jax.vmap(run, in_axes=(0, 0, 0, 0, 0, None, 0, 0, 0)))


@lru_cache(maxsize=64)
def _compiled_vinit(model, cfg, fl):
    """Jitted per-seed federation init: (seeds,) int32 -> batched state +
    PRNG keys.  Unjitted vmap would re-trace afl_init on every group."""
    def init(seeds):
        keys = jax.vmap(jax.random.key)(seeds.astype(jnp.uint32))
        return jax.vmap(lambda k: afl_init(model, cfg, fl, k))(keys)

    return jax.jit(init)


@lru_cache(maxsize=16)
def _compiled_seed_keys(seed_key_fn):
    return jax.jit(jax.vmap(seed_key_fn))


def _usable_mesh(mesh, num_seeds: int):
    """The mesh if it evenly divides the seed axis, else None (unsharded).

    Both the batched inputs and the replicated eval batch must follow the
    same decision — mixing mesh-committed and uncommitted arguments makes
    the jitted run fail with incompatible devices."""
    if mesh is None:
        return None
    size = int(np.prod(mesh.devices.shape))
    if num_seeds % size != 0:
        log.warning("seeds=%d not divisible by mesh size %d; running "
                    "unsharded", num_seeds, size)
        return None
    return mesh


def run_seed_batch(
    model,
    cfg,
    fl,
    policy_name: str,
    shard,
    eval_batch,
    seeds: Sequence[int],
    rounds: Optional[int] = None,
    eval_every: int = 20,
    mesh=None,
    telemetry=None,
) -> list[RunResult]:
    """All ``seeds`` of one grid group in a single compiled execution.

    Scenario schedules and budgets are built host-side per seed (numpy
    mobility traces), stacked to (S, rounds, N) device tensors, and the
    vmapped scan consumes them.  Returns one ``RunResult`` per seed whose
    history matches an independent ``run_afl_scanned`` of that seed.

    ``telemetry``: a ``MetricRegistry`` whose state batches over the seed
    axis (sharded with the rest when a mesh is given); each RunResult
    carries its seed's fetched snapshot — merge them with
    ``repro.telemetry.merge_fetched`` (or on device via
    ``registry.merge_stacked``).
    """
    rounds = rounds or fl.rounds
    from repro.core.runner import resolve_telemetry

    telemetry = resolve_telemetry(fl, telemetry, s=model.num_params())
    policy = BL.ALL[policy_name](model.num_params(), fl)
    epolicy = engine_policy(policy)

    providers = [
        build_provider(fl, policy_name, None, rounds, int(s)) for s in seeds
    ]
    scheds = [p.schedule() for p in providers]
    zeta = jnp.asarray(np.stack([np.asarray(z) for z, _, _ in scheds]))
    tau = jnp.asarray(np.stack([np.asarray(t) for _, t, _ in scheds]),
                      jnp.float32)
    h2 = jnp.asarray(np.stack([np.asarray(h) for _, _, h in scheds]),
                     jnp.float32)
    # heterogeneity loss masks: (S, rounds, N) per key, {} when disabled
    # (aux presence is a property of fl, so it is uniform across seeds)
    het = ({} if providers[0].aux is None else {
        k: jnp.asarray(np.stack([np.asarray(p.aux[k]) for p in providers]),
                       jnp.float32)
        for k in providers[0].aux
    })
    budgets = jnp.stack([sample_budgets(fl, int(s)) for s in seeds])

    efl = engine_fl(fl)
    seed_arr = jnp.asarray(seeds, jnp.int32)
    state0 = _compiled_vinit(model, cfg, efl)(seed_arr)
    sample_keys = _compiled_seed_keys(shard.seed_key)(seed_arr)
    eval_b = jax.device_put({k: jnp.asarray(v) for k, v in eval_batch.items()})
    ns = len(seeds)
    tstate0 = (
        jax.tree.map(lambda l: jnp.zeros((ns,) + l.shape, l.dtype),
                     telemetry.init_state())
        if telemetry is not None else {}
    )

    mesh = _usable_mesh(mesh, ns)
    if mesh is not None:
        batched = (state0, zeta, tau, h2, budgets, sample_keys, tstate0, het)
        batched = jax.device_put(
            batched, NamedSharding(mesh, P(mesh.axis_names[0]))
        )
        state0, zeta, tau, h2, budgets, sample_keys, tstate0, het = batched
        eval_b = jax.device_put(eval_b, NamedSharding(mesh, P()))

    vrun = _compiled_vrun(model, cfg, efl, epolicy, rounds, eval_every,
                          shard.traced_batch, telemetry)
    states, hist_dev, tstates = vrun(state0, zeta, tau, h2, budgets, eval_b,
                                     sample_keys, tstate0, het)

    pts = eval_points(rounds, eval_every)
    hist_np = {k: np.asarray(v) for k, v in hist_dev.items()}  # (S, E)
    out = []
    for i, s in enumerate(seeds):
        hist = {"round": list(pts)}
        hist.update({k: [float(x) for x in v[i]] for k, v in hist_np.items()})
        snap = (
            telemetry.fetch(jax.tree.map(lambda l: l[i], tstates))
            if telemetry is not None else None
        )
        out.append(RunResult(
            policy_name, hist, hist["eval"][-1],
            jax.tree.map(lambda l: l[i], states),
            telemetry=snap,
        ))
    return out
