"""Federated training driver (simulation mode — the paper's experiments).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch resnet9-cifar10 \
      --policy mads --rounds 200 --devices 20 --speed 10
  PYTHONPATH=src python -m repro.launch.train --arch lanegcn-argoverse \
      --policy afl-spar --rounds 100
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --policy mads --rounds 50    # federated LLM fine-tuning (reduced)

Synthetic stand-ins for CIFAR-10 / Argoverse / token corpora are generated
on the fly (offline container; DESIGN.md §7).  Checkpoints + a JSON metrics
history land in --workdir.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.checkpoint import save
from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.runner import resolve_telemetry, run_afl
from repro.data import (
    DeviceLoader,
    SyntheticCifar,
    SyntheticTokens,
    SyntheticTrajectories,
    dirichlet_partition,
)
from repro.models.registry import build_model
from repro.telemetry import (
    JsonlSink,
    PhaseTracer,
    TelemetrySuite,
    report_from_config,
    to_jsonable,
)
from repro.utils import get_logger

log = get_logger("repro.train")


def build_device_data(cfg, fl, *, train_n=2000, eval_n=512, seq_len=64, seed=0):
    """Per-family synthetic datasets partitioned across devices.

    Returns (per-device array dicts, eval batch) — feed the list to a
    ``DeviceLoader`` (host sampling) or a ``repro.experiments.DataShard``
    (device-resident, in-scan sampling).
    """
    if cfg.family == "vision":
        ds = SyntheticCifar(seed=seed)
        imgs, labels = ds.make_split(train_n, seed=seed + 1)
        parts = dirichlet_partition(labels, fl.num_devices, fl.dirichlet_rho, seed)
        dev = [{"images": imgs[p], "labels": labels[p]} for p in parts]
        ev = dict(zip(("images", "labels"), ds.make_split(eval_n, seed=seed + 2)))
    elif cfg.family == "trajectory":
        ds = SyntheticTrajectories(seed=seed)
        data = ds.make_split(train_n, seed=seed + 1)
        order = np.random.default_rng(seed).permutation(train_n)
        chunks = np.array_split(order, fl.num_devices)
        dev = [{k: v[c] for k, v in data.items()} for c in chunks]
        ev = ds.make_split(eval_n, seed=seed + 2)
    else:  # language families: order-1 Markov streams
        ds = SyntheticTokens(vocab_size=cfg.vocab_size, seed=seed)
        data = ds.make_split(train_n // 4, seq_len, seed=seed + 1)
        order = np.random.default_rng(seed).permutation(len(data["tokens"]))
        chunks = np.array_split(order, fl.num_devices)
        dev = [{k: v[c] for k, v in data.items()} for c in chunks]
        ev = ds.make_split(eval_n // 4, seq_len, seed=seed + 2)
    return dev, ev


def build_federation(cfg, fl, *, train_n=2000, eval_n=512, seq_len=64, seed=0):
    """``build_device_data`` wrapped in the host-side DeviceLoader."""
    dev, ev = build_device_data(
        cfg, fl, train_n=train_n, eval_n=eval_n, seq_len=seq_len, seed=seed
    )
    return DeviceLoader(dev, fl.batch_size, seed), ev


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet9-cifar10")
    ap.add_argument("--policy", default="mads", choices=sorted(BL.ALL))
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--devices", type=int, default=20)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--rho", type=float, default=0.5, help="non-iid Dirichlet level")
    ap.add_argument("--speed", type=float, default=0.0, help="m/s; 0 = direct c/lambda")
    ap.add_argument("--mobility", default="exponential",
                    choices=["exponential", "rwp", "gauss_markov", "manhattan",
                             "hotspot", "static"],
                    help="scenario engine mobility model (repro/scenarios)")
    ap.add_argument("--scenario-backend", default="numpy",
                    choices=["numpy", "jax"],
                    help="scenario engine: numpy oracle kinematics or the "
                         "device-resident jax port (trace models only; "
                         "repro/scenarios/jax_kinematics)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="heterogeneity dropout prob (fl.het_dropout)")
    ap.add_argument("--availability", type=float, default=1.0,
                    help="heterogeneity: stationary P(client available)")
    ap.add_argument("--compute-mean", type=float, default=0.0,
                    help="heterogeneity: mean Exp compute latency (s) "
                         "subtracted from each contact window")
    ap.add_argument("--area", type=float, default=1000.0, help="m, square side")
    ap.add_argument("--comm-range", type=float, default=100.0)
    ap.add_argument("--contact", type=float, default=4.0)
    ap.add_argument("--intercontact", type=float, default=400.0)
    ap.add_argument("--v-weight", type=float, default=1e-4)
    ap.add_argument("--reduced", action="store_true", help="use the reduced variant")
    ap.add_argument("--width", type=int, default=0,
                    help=">0: override d_model (CPU-sized smoke runs, "
                         "same knob as sweep.py)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--train-n", type=int, default=2000)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--engine", default="scan", choices=["scan", "loop"],
                    help="scan: whole run as one compiled lax.scan program "
                         "(repro/experiments); loop: per-round dispatch")
    ap.add_argument("--telemetry", action="store_true",
                    help="device-resident round metrics (repro/telemetry): "
                         "staleness/bits/tau histograms + counters, written "
                         "to workdir/telemetry.jsonl")
    ap.add_argument("--perdevice", action="store_true",
                    help="also carry the per-device flight recorder (implies "
                         "--telemetry): (N,) participation/staleness/tau/"
                         "bits/energy rows, straggler table at the end")
    ap.add_argument("--probes", action="store_true",
                    help="also carry the online theory probes (implies "
                         "--telemetry): theory-vs-measured deltas against "
                         "core/theory.py closed forms, emitted as a "
                         "probe_report event")
    ap.add_argument("--profile-dir", default="",
                    help="jax.profiler trace dir; also annotates the "
                         "compile/execute/eval phase spans")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workdir", default="runs/train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.width > 0:
        cfg = cfg.replace(d_model=args.width)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=args.devices, rounds=args.rounds, batch_size=args.batch_size,
        learning_rate=args.lr, dirichlet_rho=args.rho, speed=args.speed,
        mobility_model=args.mobility, area=args.area, comm_range=args.comm_range,
        mean_contact=args.contact, mean_intercontact=args.intercontact,
        lyapunov_v=args.v_weight, seed=args.seed,
        scenario_backend=args.scenario_backend,
        het_dropout=args.dropout, het_availability=args.availability,
        het_compute_mean=args.compute_mean,
        sparsifier="exact" if model.num_params() < 2_000_000 else "sampled",
        telemetry=args.telemetry or args.perdevice or args.probes,
        telemetry_perdevice=args.perdevice,
        telemetry_probes=args.probes,
    )
    log.info("arch=%s params=%d policy=%s rounds=%d devices=%d",
             cfg.name, model.num_params(), args.policy, args.rounds, args.devices)

    dev, ev = build_device_data(
        cfg, fl, train_n=args.train_n, seq_len=args.seq_len, seed=args.seed
    )
    if args.engine == "scan":
        # device-resident shard sampled inside the scan; a DeviceLoader
        # would make the engine prestack every round's batches on device
        from repro.experiments import DataShard

        loader = DataShard(dev, fl.batch_size, seed=args.seed)
    else:
        loader = DeviceLoader(dev, fl.batch_size, args.seed)

    tracer = PhaseTracer(profile_dir=args.profile_dir or None)
    tracer.start()
    try:
        res = run_afl(model, cfg, fl, args.policy, loader, ev,
                      rounds=args.rounds, eval_every=args.eval_every,
                      log_progress=True, engine=args.engine, tracer=tracer)
    finally:
        tracer.stop()

    os.makedirs(args.workdir, exist_ok=True)
    save(args.workdir, args.rounds, res.state.w)
    with open(os.path.join(args.workdir, "history.json"), "w") as f:
        json.dump({"args": vars(args), "history": res.history}, f, indent=2)
    # the same resolution run_afl used — registry alone, or the suite
    # carrying the per-device table / theory probes
    telemetry = resolve_telemetry(fl, None, s=model.num_params())
    with JsonlSink(os.path.join(args.workdir, "telemetry.jsonl")) as sink:
        sink.extend(tracer.events())
        if res.telemetry is not None:
            sink.emit({"kind": "metrics", **to_jsonable(res.telemetry)})
            if (isinstance(telemetry, TelemetrySuite)
                    and telemetry.probes is not None
                    and res.telemetry.get("probes") is not None):
                rep = report_from_config(
                    telemetry.probes, res.telemetry["probes"], fl)
                sink.emit({"kind": "probe_report", **rep})
    if res.telemetry is not None and telemetry is not None:
        print(telemetry.summary(res.telemetry))
    log.info("phase wall clock:\n%s", tracer.summary())
    log.info("final eval=%.4f; wrote %s", res.final_eval, args.workdir)


if __name__ == "__main__":
    main()
