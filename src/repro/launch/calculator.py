"""Analytic roofline calculator (per arch x shape x mesh).

XLA's ``cost_analysis`` counts ``while`` bodies ONCE, so scanned-layer
models under-report FLOPs/bytes by ~num_layers (verified empirically; see
EXPERIMENTS.md §Dry-run).  The dry-run therefore records BOTH the raw HLO
numbers and these analytic estimates; roofline terms use the analytic
values, with the HLO artifact supplying the collective *structure* (which
collectives, shapes, groups) and the memory_analysis (per-device residency).

Formulas (documented napkin math):
* dense/moe/vlm attention layer fwd FLOPs per token (context c):
    qkvo projections 2*d*(2*H*hd + 2*KV*hd) + scores/values 2*2*c*H*hd
* MLP 3 matmuls (SwiGLU): 3*2*d*f; MoE: shared + top_k routed + router.
* Mamba2 (SSD): projections 2*d*(2*di + 2*n + h) + out 2*di*d
    + SSD intra-chunk 2*2*Q*di + state path 2*2*di*n.
* vocab head 2*d*V (+ tied embed read).
* train = 3x fwd (fwd + 2x bwd); AFL adds 4 elementwise passes over the
  client states (sparsify/error/aggregate/apply) — memory, not flops.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import InputShape, ModelConfig

BYTES = {"bfloat16": 2, "float32": 4, "float16": 2}


def _attn_layer_flops(cfg: ModelConfig, ctx: float) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * d * (2 * h * hd + 2 * kv * hd)
    attn = 2 * 2 * ctx * h * hd
    return proj + attn


def _mlp_layer_flops(cfg: ModelConfig) -> float:
    if not cfg.is_moe:
        return 3 * 2 * cfg.d_model * cfg.d_ff
    f = cfg.moe_d_ff or cfg.d_ff
    routed = cfg.num_experts_per_tok * 3 * 2 * cfg.d_model * f
    shared = cfg.num_shared_experts * 3 * 2 * cfg.d_model * f
    router = 2 * cfg.d_model * cfg.num_experts
    return routed + shared + router


def _mamba_layer_flops(cfg: ModelConfig, chunk_eff: float) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = cfg.ssm_heads or di // 64
    proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d
    ssd = 2 * 2 * chunk_eff * di + 2 * 2 * di * n
    return proj + ssd


def fwd_flops_per_token(cfg: ModelConfig, ctx: float, decode: bool = False) -> float:
    """Forward FLOPs per (decoder) token at attention context ``ctx``."""
    v = 2 * cfg.d_model * cfg.vocab_size
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        if cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        per_layer = _attn_layer_flops(cfg, ctx) + _mlp_layer_flops(cfg)
        return cfg.num_layers * per_layer + v
    if fam == "ssm":
        chunk_eff = 1.0 if decode else cfg.ssm_chunk
        return cfg.num_layers * _mamba_layer_flops(cfg, chunk_eff) + v
    if fam == "hybrid":
        chunk_eff = 1.0 if decode else cfg.ssm_chunk
        n_attn = max((cfg.num_layers - 1) // cfg.attn_every, 1)
        mamba = cfg.num_layers * _mamba_layer_flops(cfg, chunk_eff)
        attn = n_attn * _attn_layer_flops(cfg, ctx)
        return mamba + attn + v
    if fam == "audio":
        # decoder: self-attn (ctx) + cross-attn (encoder_seq) + gelu mlp
        d, f = cfg.d_model, cfg.d_ff
        self_a = _attn_layer_flops(cfg, ctx)
        cross = _attn_layer_flops(cfg, cfg.encoder_seq)
        mlp = 2 * 2 * d * f
        return cfg.num_layers * (self_a + cross + mlp) + v
    raise ValueError(fam)


def encoder_flops(cfg: ModelConfig) -> float:
    """Whisper encoder, per sequence (not per decoder token)."""
    if cfg.family != "audio":
        return 0.0
    s = cfg.encoder_seq
    per_tok = cfg.encoder_layers * (
        _attn_layer_flops(cfg, s) + 2 * 2 * cfg.d_model * cfg.d_ff
    )
    return per_tok * s


@dataclasses.dataclass
class Analytic:
    flops_total: float  # whole step, all devices
    flops_per_device: float
    hbm_bytes_per_device: float
    tokens: int


def step_analytics(cfg: ModelConfig, shape: InputShape, world: int,
                   num_params: int, *, num_clients: int = 0,
                   model_parallel: int = 0) -> Analytic:
    b, s = shape.global_batch, shape.seq_len
    pb = BYTES.get(cfg.param_dtype, 2)
    ab = BYTES.get(cfg.dtype, 2)
    # model-parallel degree: parameters are sharded over `model` (16) by
    # default; the dp_client rules variant replicates params (mp=1)
    mp = model_parallel or (16 if world >= 256 else max(world // 2, 1))

    if shape.kind == "train":
        tokens = b * s
        f_tok = fwd_flops_per_token(cfg, ctx=s / 2)
        flops = 3.0 * f_tok * tokens + encoder_flops(cfg) * b * 3.0
        # HBM per device: each client slice touches its 3 states + grads +
        # upload/error temporaries: ~9 model-sized passes over params/mp,
        # plus activations once fwd + once bwd.
        params_dev = num_params / mp * pb
        act_dev = tokens / max(world // mp, 1) * cfg.d_model * max(cfg.num_layers, 1) * 6 * ab
        hbm = 9.0 * params_dev + 2.0 * act_dev
        return Analytic(flops, flops / world, hbm, tokens)

    if shape.kind == "prefill":
        tokens = b * s
        f_tok = fwd_flops_per_token(cfg, ctx=s / 2)
        flops = f_tok * tokens + encoder_flops(cfg) * b
        params_dev = num_params / mp * pb
        act_dev = tokens / max(world // mp, 1) * cfg.d_model * max(cfg.num_layers, 1) * 4 * ab
        hbm = params_dev + act_dev
        return Analytic(flops, flops / world, hbm, tokens)

    # decode
    tokens = b
    ctx = s
    f_tok = fwd_flops_per_token(cfg, ctx=ctx, decode=True)
    flops = f_tok * tokens
    params_dev = num_params / mp * pb
    if cfg.is_moe and getattr(cfg, "expert_dtype", "") == "int8":
        f = cfg.moe_d_ff or cfg.d_ff
        expert_params = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * f
        params_dev -= expert_params / mp * (pb - 1)  # experts stored 1B/elem
    kv_b = 1 if getattr(cfg, "kv_cache_dtype", "") == "int8" else ab
    # KV-cache read per token decode
    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        eff = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        if cfg.family == "hybrid":
            n_kv_layers = max((cfg.num_layers - 1) // cfg.attn_every, 1)
            eff = min(ctx, 8192)
        else:
            n_kv_layers = cfg.num_layers
        cache = b * eff * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * kv_b * n_kv_layers
    else:
        cache = 0.0
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.ssm_heads or di // 64
        cache += cfg.num_layers * b * h * 64 * cfg.ssm_state * 4 * 2  # f32 rw
    # the cache is sharded over BOTH mesh axes (batch/seq on data, heads or
    # head_dim on model), so per-device traffic is cache/world.
    hbm = params_dev + cache / world
    return Analytic(flops, flops / world, hbm, tokens)
