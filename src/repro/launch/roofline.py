"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §6).

Terms (per device, seconds):
  compute    = FLOPs / peak_FLOPs              (197 TFLOP/s bf16, v5e-class)
  memory     = HBM bytes / HBM_bw              (819 GB/s)
  collective = per-device ICI bytes / link_bw  (50 GB/s)

Sources: XLA's ``cost_analysis`` counts ``while`` (=``lax.scan``) bodies
ONCE, so for scanned-layer models it under-reports by ~num_layers.  The
compute/memory terms therefore come from the analytic calculator
(``launch/calculator.py``); the HLO text supplies the collective structure,
with collectives found inside while-loop bodies scaled by the layer-scan
trip count.  Raw HLO numbers are retained in every record for cross-checks.

Ring-algorithm byte factors:
  all-reduce       2 (g-1)/g * result_bytes
  all-gather         (g-1)/g * result_bytes (result = gathered tensor)
  reduce-scatter     (g-1)   * result_bytes (result = local shard)
  all-to-all         (g-1)/g * result_bytes
  collective-permute          result_bytes

``cost_analysis``/``as_text`` of a GSPMD-partitioned executable describe the
per-device program, so every term here is already per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"all-reduce-start|all-gather-start|collective-permute-start)\b(.*)$"
)
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_BODY_REF_RE = re.compile(r"body=%?([\w.\-]+)")
_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)  # iota v2: [num_groups, group_size]
    return world


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    scanned_bytes: float = 0.0  # portion that was scaled by scan trips

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, world: int, scan_trips: int = 1) -> CollectiveStats:
    # first pass: which computations are while-loop bodies?
    bodies = set(_BODY_REF_RE.findall(hlo_text))
    bytes_by: Dict[str, float] = {}
    count_by: Dict[str, int] = {}
    scanned = 0.0
    current = ""
    for line in hlo_text.splitlines():
        head = _COMP_HEAD_RE.match(line.strip())
        if head:
            current = head.group(1)
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        result, kind, _rest = m.groups()
        kind = kind.replace("-start", "")
        g = _group_size(line, world)
        rb = _shape_bytes(result)
        if kind == "all-reduce":
            moved = 2.0 * (g - 1) / g * rb
        elif kind == "all-gather":
            moved = (g - 1) / g * rb
        elif kind == "reduce-scatter":
            moved = float(g - 1) * rb
        elif kind == "all-to-all":
            moved = (g - 1) / g * rb
        else:
            moved = float(rb)
        if current in bodies:
            moved *= scan_trips
            scanned += moved
        bytes_by[kind] = bytes_by.get(kind, 0.0) + moved
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by, scanned)


def model_flops(num_params: int, tokens: int, active_params: int | None = None,
                train: bool = False) -> float:
    """MODEL_FLOPS = 6 N D for training (2 N D serving); MoE uses N_active."""
    mult = 6.0 if train else 2.0
    return mult * float(active_params or num_params) * float(tokens)


@dataclasses.dataclass
class Roofline:
    flops: float  # analytic, per device
    hbm_bytes: float  # analytic, per device
    coll_bytes: float  # HLO-parsed (scan-scaled), per device
    hlo_flops_raw: float  # cost_analysis (scan bodies counted once)
    hlo_bytes_raw: float
    coll_detail: Dict[str, float]
    coll_counts: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / analytic total FLOPs

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str, world: int, *, model_flops_total: float,
            analytic=None, scan_trips: int = 1) -> Roofline:
    ca = compiled.cost_analysis() or {}
    hlo_flops = float(ca.get("flops", 0.0))
    hlo_bytes = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text, world, scan_trips)
    flops_dev = analytic.flops_per_device if analytic else hlo_flops
    hbm_dev = analytic.hbm_bytes_per_device if analytic else hlo_bytes
    flops_total = analytic.flops_total if analytic else hlo_flops * world
    t_c = flops_dev / PEAK_FLOPS
    t_m = hbm_dev / HBM_BW
    t_x = coll.total_bytes / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_total / max(flops_total, 1e-9)
    return Roofline(
        flops=flops_dev, hbm_bytes=hbm_dev, coll_bytes=coll.total_bytes,
        hlo_flops_raw=hlo_flops, hlo_bytes_raw=hlo_bytes,
        coll_detail=coll.bytes_by_kind, coll_counts=coll.count_by_kind,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops_total=model_flops_total,
        useful_ratio=useful,
    )
