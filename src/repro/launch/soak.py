"""Soak the streaming ingestion server: sustained uploads/sec.

Generates a population of compressed uploads with the engines' own codec
invocation (``core.afl.compress_uploads`` — the same function both the
single-host and pjit rounds call), serialises them to the wire format,
and drives them through ``serve.IngestServer`` in a bounded-queue
producer/consumer loop, measuring sustained aggregation throughput:

    PYTHONPATH=src python -m repro.launch.soak --uploads 10000 \
        --batch 256 --params 4096 --staleness hinge --out-dir out/

The per-upload loop baseline (the fused op at batch=1 — what a naive
server does) runs alongside; ``speedup_vs_loop`` is the headline number
and ``BENCH_serve.json`` (``--out-dir``) feeds the
``tools/bench_compare.py`` CI gate.  ``--mesh N`` shards the batch axis
over N simulated host devices (``core.distributed.ingest_shardings``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

__all__ = ["run_soak", "make_payloads", "main"]

_CODECS = ("topk", "topk32", "qsgd", "joint", "fixed-kb")


def _make_codec(name: str, s: int):
    from repro.compression import (FixedKbCompressor, JointCompressor,
                                   QSGDCompressor, TopKCompressor)

    if name == "topk":
        return TopKCompressor(s=s, u=8)
    if name == "topk32":
        return TopKCompressor(s=s, u=32)
    if name == "qsgd":
        return QSGDCompressor(s=s)
    if name == "joint":
        return JointCompressor(s=s)
    if name == "fixed-kb":
        return FixedKbCompressor(s=s, b=8)
    raise ValueError(f"unknown codec {name!r}; known: {_CODECS}")


def make_payloads(uploads: int, s: int, max_k: int, *, codec: str = "topk",
                  max_stale: int = 32, seed: int = 0, chunk: int = 512):
    """Compress ``uploads`` synthetic gradients and serialise to the wire.

    Chunks of devices go through ``compress_uploads`` (vmap over the
    chunk, EF state threaded — exactly the engines' codec pass); each
    device's dense payload is then encoded host-side with the codec's
    reported ``(step, b)`` so quantised codecs ship integer grid codes.
    Upload round tags are back-dated up to ``max_stale`` rounds so the
    staleness-weight family has a spread of ``delta_tau`` to act on.
    """
    import jax
    import jax.numpy as jnp

    from repro.compression.wire import encode_upload, index_bits
    from repro.core.afl import compress_uploads

    comp = _make_codec(codec, s)
    shapes = {"layer0": (s // 2,), "layer1": (s - s // 2,)}
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    # budgets that keep k within the wire's max_k (dense qsgd ships k = s)
    u_bits = 32 if codec == "topk32" else 8
    cap = float(max_k) * (u_bits + index_bits(s))
    payloads = []
    for lo in range(0, uploads, chunk):
        n = min(chunk, uploads - lo)
        key, kg, kc = jax.random.split(key, 3)
        g_n = {name: jax.random.normal(jax.random.fold_in(kg, i),
                                       (n,) + shp, jnp.float32)
               for i, (name, shp) in enumerate(shapes.items())}
        e_n = jax.tree.map(jnp.zeros_like, g_n)
        budgets = jnp.asarray(
            rng.uniform(0.25, 1.0, size=n) * cap, jnp.float32)
        upload, _, cstats, _ = compress_uploads(comp, g_n, e_n, kc,
                                                budgets, n)
        up_np = {k: np.asarray(v) for k, v in upload.items()}
        step_np = np.asarray(cstats["step"], np.float64)
        b_np = np.asarray(cstats["b"], np.float64)
        stale = rng.integers(0, max_stale, size=n)
        for i in range(n):
            payloads.append(encode_upload(
                {k: v[i] for k, v in up_np.items()},
                b=b_np[i] if b_np[i] > 0 else 32.0, step=float(step_np[i]),
                device=lo + i, rnd=-int(stale[i]), max_k=max_k))
    return payloads


def _drain_all(server, payloads) -> None:
    """Producer/consumer loop: offer until backpressure, then step."""
    i, n = 0, len(payloads)
    while i < n or len(server.buffer):
        while i < n:
            if server.submit(payloads[i]):
                i += 1
            elif server.buffer.policy == "reject":
                i += 1  # refused for good — counted, client re-uploads later
            else:
                break  # deferred: retry the same payload after a step
        server.step()


def run_soak(*, uploads: int = 10_000, batch: int = 256, s: int = 4096,
             max_k: int = 256, codec: str = "topk",
             staleness_family: str = "constant", alpha: float = 1.0,
             queue_cap: int = 0, queue_policy: str = "defer",
             mode: str = "parity", baseline: bool = True,
             baseline_n: int = 2048, mesh=None, seed: int = 0,
             tracer=None) -> dict:
    """One soak point; returns throughput numbers + the telemetry snapshot."""
    import jax
    import jax.numpy as jnp

    from repro.core.afl import StalenessWeight
    from repro.compression.wire import pack_batch
    from repro.serve import IngestServer
    from repro.telemetry.tracing import PhaseTracer

    tracer = tracer or PhaseTracer()
    if codec == "qsgd":
        max_k = s  # dense codec: every coordinate rides the wire
    with tracer.span("soak.generate", uploads=uploads):
        payloads = make_payloads(uploads, s, max_k, codec=codec, seed=seed)
    sw = StalenessWeight(family=staleness_family, alpha=alpha)
    w = {"layer0": jnp.zeros((s // 2,), jnp.float32),
         "layer1": jnp.zeros((s - s // 2,), jnp.float32)}

    def build(b, cap):
        srv = IngestServer(
            w, num_devices=uploads, batch=b, max_k=max_k, staleness=sw,
            queue_capacity=cap, queue_policy=queue_policy, mesh=mesh,
            mode=mode, tracer=tracer)
        # warm the jit outside the timed region (ingest is pure: discard)
        packed = pack_batch([], s=srv.s, max_k=max_k, batch=b)
        if srv._shardings is not None:
            packed = {k: jax.device_put(v, srv._shardings["batch"])
                      for k, v in packed.items()}
        jax.block_until_ready(srv._ingest(srv.w, packed, srv.tstate))
        return srv

    with tracer.span("soak.fused", uploads=uploads):
        server = build(batch, queue_cap or 4 * batch)
        t0 = time.perf_counter()
        _drain_all(server, payloads)
        jax.block_until_ready(server.w)
        fused_wall = time.perf_counter() - t0
    snap = server.snapshot()
    done = snap["counters"]["ingested"]
    out = {
        "uploads": uploads, "batch": batch, "s": s, "max_k": max_k,
        "codec": codec, "staleness": staleness_family, "mode": mode,
        "fused_wall_s": fused_wall, "fused_per_s": done / fused_wall,
        "snapshot": snap, "server": server,
    }
    if baseline:
        nb = min(uploads, baseline_n)
        with tracer.span("soak.loop_baseline", uploads=nb):
            loop_srv = build(1, max(queue_cap, 4 * batch) or 4 * batch)
            t0 = time.perf_counter()
            _drain_all(loop_srv, payloads[:nb])
            jax.block_until_ready(loop_srv.w)
            loop_wall = time.perf_counter() - t0
        out["loop_per_s"] = nb / loop_wall
        out["speedup_vs_loop"] = out["fused_per_s"] / out["loop_per_s"]
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--uploads", type=int, default=10_000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--params", type=int, default=4096,
                    help="flat model size s")
    ap.add_argument("--max-k", type=int, default=256,
                    help="wire payload coordinate capacity")
    ap.add_argument("--codec", default="topk", choices=_CODECS)
    ap.add_argument("--staleness", default="constant",
                    choices=("constant", "hinge", "poly"))
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="arrival buffer capacity (0 = 4x batch)")
    ap.add_argument("--queue-policy", default="defer",
                    choices=("reject", "defer"))
    ap.add_argument("--mode", default="parity",
                    choices=("parity", "scatter"))
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the batch over N simulated host devices")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the per-upload loop baseline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small point (CI): 1500 uploads, s=2048")
    ap.add_argument("--out-dir", default="",
                    help="export BENCH_serve.json here")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh > 1:
        from repro.launch.mesh import force_host_device_count
        force_host_device_count(args.mesh)
        import jax
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[: args.mesh]).reshape(args.mesh, 1)
        mesh = Mesh(devs, ("data", "model"))

    if args.smoke:
        args.uploads, args.params = min(args.uploads, 1500), 2048
        args.batch, args.max_k = min(args.batch, 128), min(args.max_k, 128)

    from repro.telemetry import export_bench
    from repro.telemetry.tracing import PhaseTracer

    tracer = PhaseTracer()
    res = run_soak(
        uploads=args.uploads, batch=args.batch, s=args.params,
        max_k=args.max_k, codec=args.codec,
        staleness_family=args.staleness, alpha=args.alpha,
        queue_cap=args.queue_cap, queue_policy=args.queue_policy,
        mode=args.mode, baseline=not args.no_baseline, mesh=mesh,
        seed=args.seed, tracer=tracer)

    server = res.pop("server")
    print(server.registry.summary(res["snapshot"]))
    print(tracer.summary())
    name = (f"soak_{args.codec}_{args.staleness}"
            f"_n{args.uploads}_b{args.batch}_s{args.params}")
    derived = f"uploads_per_s={res['fused_per_s']:.0f}"
    if "speedup_vs_loop" in res:
        derived += (f";loop_per_s={res['loop_per_s']:.0f}"
                    f";speedup_vs_loop={res['speedup_vs_loop']:.1f}x")
    row = f"{name},{res['fused_wall_s'] / max(args.uploads, 1) * 1e6:.1f},{derived}"
    print(row)
    if args.out_dir:
        export_bench("serve", [row], args.out_dir)


if __name__ == "__main__":
    main()
