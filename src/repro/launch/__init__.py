"""Launchers: simulation training, distributed dry-run, serving, roofline.

Deliberately empty of imports — several submodules set XLA flags or touch
jax device state at import time and must only be imported explicitly.
"""
