"""Grid sweep CLI — a paper-style comparison table in one command.

    PYTHONPATH=src python -m repro.launch.sweep \
        --arch resnet9-cifar10 --policies mads,afl-spar,afl \
        --speeds 5,10,20 --mobility exponential --seeds 3 \
        --rounds 60 --devices 8 --out runs/sweep

Compression-codec comparison (one command, resumable — how the same
contact bit budget is best spent; see repro/compression):

    PYTHONPATH=src python -m repro.launch.sweep \
        --arch resnet9-cifar10 --policies mads,mads-joint,qsgd,fixed-kb \
        --speeds 10 --seeds 3 --rounds 60 --out runs/codecs

``--codec`` is shorthand for a single codec policy (topk | joint | qsgd |
fixed-kb), ``--per-layer`` upgrades the joint codec to per-leaf (k_l, b_l)
budgets, and ``--mesh N`` forces N simulated host devices so the seed axis
shards (CI-scale stand-in for a real mesh):

    PYTHONPATH=src python -m repro.launch.sweep \
        --arch resnet9-cifar10 --codec joint --per-layer --mesh 2 \
        --seeds 2 --rounds 20 --out runs/perlayer

Every (policy, mobility, speed) group runs its seeds in ONE vmapped
compiled program (repro/experiments); completed cells found in --out are
skipped, so an interrupted sweep resumes.  Results: per-cell npz histories
+ results.jsonl under --out, and a final mean±CI table on stdout.
"""
from __future__ import annotations

import argparse
import os
import time
from contextlib import nullcontext

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.experiments import (
    DataShard,
    ExperimentGrid,
    ResultsStore,
    run_seed_batch,
)
from repro.launch.mesh import make_seed_mesh
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import (
    AFL_REGISTRY,
    DeviceTable,
    JsonlSink,
    PhaseTracer,
    TelemetrySuite,
    TheoryProbes,
    merge_fetched,
    render_report,
    report_from_config,
    to_jsonable,
)
from repro.utils import get_logger

log = get_logger("repro.sweep")


def run_sweep(grid: ExperimentGrid, store: ResultsStore, model, cfg, shard,
              eval_batch, mesh=None, metric: str = "eval", telemetry=None,
              tracer=None, sink=None) -> str:
    """Execute every pending cell of ``grid`` into ``store``; returns the
    comparison table.

    ``telemetry`` (a ``repro.telemetry.MetricRegistry`` or
    ``TelemetrySuite``) instruments every group's vmapped run; per-group
    merged snapshots land in ``sink`` (a ``JsonlSink``) as
    ``group_metrics`` events plus one sweep-wide ``metrics`` event.  A
    suite with probes additionally emits one ``probe_report`` event per
    group — the theory closed forms evaluated at that group's (c, lam,
    delta) contact point.  ``tracer`` records one span per executed group.
    """
    span = tracer.span if tracer is not None else (
        lambda name, **kw: nullcontext())
    probes = telemetry.probes if isinstance(telemetry, TelemetrySuite) \
        else None
    snapshots = []
    for policy, mobility, speed, dropout, cells in grid.groups():
        todo = store.pending(cells)
        if not todo:
            log.info("group %s: all %d seeds done, skipping",
                     cells[0].group_key, len(cells))
            continue
        fl = grid.fl_for(mobility, speed, dropout)
        t0 = time.time()
        with span("group", group=cells[0].group_key):
            results = run_seed_batch(
                model, cfg, fl, policy, shard, eval_batch,
                seeds=[c.seed for c in todo], rounds=grid.rounds,
                eval_every=grid.eval_every, mesh=mesh, telemetry=telemetry,
            )
        wall = time.time() - t0
        for cell, res in zip(todo, results):
            store.save(cell, res.history,
                       meta={"arch": cfg.name, "rounds": grid.rounds,
                             "wall_s": round(wall / len(todo), 3)})
        snaps = [r.telemetry for r in results if r.telemetry is not None]
        if snaps:
            gsnap = merge_fetched(snaps)
            snapshots.append(gsnap)
            if sink is not None:
                sink.emit({"kind": "group_metrics",
                           "group": cells[0].group_key,
                           "seeds": len(todo), **to_jsonable(gsnap)})
                if probes is not None and gsnap.get("probes") is not None:
                    rep = report_from_config(probes, gsnap["probes"], fl)
                    sink.emit({"kind": "probe_report",
                               "group": cells[0].group_key, **rep})
        log.info("group %s: %d seeds in %.1fs (%.1f rounds/s)",
                 cells[0].group_key, len(todo), wall,
                 grid.rounds * len(todo) / max(wall, 1e-9))
    if snapshots:
        total = merge_fetched(snapshots)
        if sink is not None:
            sink.emit({"kind": "metrics", **to_jsonable(total)})
        if telemetry is not None:
            log.info("sweep metrics:\n%s", telemetry.summary(total))
    return store.table(grid, metric)


# --codec shorthand -> the policy (MADS power, codec-only difference)
CODEC_POLICIES = {
    "topk": "mads-topk",
    "joint": "mads-joint",
    "qsgd": "qsgd",
    "fixed-kb": "fixed-kb",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet9-cifar10")
    ap.add_argument("--policies", default="mads,afl-spar,afl",
                    help="comma-separated subset of: " + ",".join(BL.ALL))
    ap.add_argument("--codec", choices=sorted(CODEC_POLICIES),
                    help="single-codec shorthand; overrides --policies")
    ap.add_argument("--per-layer", action="store_true",
                    help="joint codec: per-leaf (k_l, b_l) bit budgets "
                         "(repro/compression/perlayer.py)")
    ap.add_argument("--mesh", type=int, default=0,
                    help=">1: force this many simulated host devices "
                         "(must run before jax initialises; the seed axis "
                         "shards over them when divisible)")
    ap.add_argument("--mobility", default="exponential",
                    help="comma-separated mobility models "
                         "(exponential|rwp|gauss_markov|manhattan|hotspot|static)")
    ap.add_argument("--speeds", default="10",
                    help="comma-separated device speeds (m/s)")
    ap.add_argument("--dropouts", default="0",
                    help="comma-separated heterogeneity dropout levels "
                         "(fl.het_dropout; repro/scenarios/heterogeneity)")
    ap.add_argument("--scenario-backend", default="numpy",
                    choices=["numpy", "jax"],
                    help="scenario engine: numpy oracle kinematics or the "
                         "device-resident jax port (trace models only)")
    ap.add_argument("--seeds", type=int, default=3,
                    help="seeds per cell (0..seeds-1)")
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--train-n", type=int, default=800)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--contact-const", type=float, default=40.0)
    ap.add_argument("--intercontact-const", type=float, default=300.0)
    ap.add_argument("--energy", type=float, nargs=2, default=(40.0, 80.0))
    ap.add_argument("--fixed-k-frac", type=float, default=0.01,
                    help="fixed-kb codec: keep-fraction target")
    ap.add_argument("--fixed-bits", type=int, default=8,
                    help="fixed-kb codec: value bit-width")
    ap.add_argument("--staleness", default="constant",
                    choices=("constant", "hinge", "poly"),
                    help="alpha * s(delta_tau) mixing family "
                         "(core.afl.StalenessWeight; shared with "
                         "repro/serve)")
    ap.add_argument("--staleness-alpha", type=float, default=1.0,
                    help="mixing weight scale alpha")
    ap.add_argument("--b-range", type=int, nargs=2, default=(2, 16),
                    help="joint/qsgd codecs: value bit-width search range")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--width", type=int, default=0,
                    help=">0: override d_model (CPU-sized sweeps)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the device-resident metric registry "
                         "(on by default; snapshots land in "
                         "--out/telemetry.jsonl)")
    ap.add_argument("--perdevice", action="store_true",
                    help="carry the per-device flight recorder "
                         "(repro/telemetry/perdevice.py): (N,) rows of "
                         "participation/staleness/tau/bits/energy, "
                         "straggler table at fetch")
    ap.add_argument("--probes", action="store_true",
                    help="carry the online theory probes "
                         "(repro/telemetry/probes.py): one probe_report "
                         "event per group comparing measured "
                         "error/staleness/success against core/theory.py")
    ap.add_argument("--report", action="store_true",
                    help="render --out/report.md from the telemetry "
                         "events after the sweep (same renderer as "
                         "tools/report.py)")
    ap.add_argument("--profile-dir", default="",
                    help="jax.profiler trace dir for the sweep")
    ap.add_argument("--out", default="runs/sweep")
    args = ap.parse_args()

    if args.mesh > 1:
        # before any jax device use — the backend initialises lazily
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(args.mesh)
    if args.codec:
        args.policies = CODEC_POLICIES[args.codec]

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.width > 0:
        cfg = cfg.replace(d_model=args.width)
    model = build_model(cfg)

    base = FLConfig(
        num_devices=args.devices, rounds=args.rounds,
        batch_size=args.batch_size, learning_rate=args.lr,
        dirichlet_rho=args.rho, contact_const=args.contact_const,
        intercontact_const=args.intercontact_const,
        energy_budget=tuple(args.energy),
        sparsifier="exact" if model.num_params() < 2_000_000 else "sampled",
        fixed_k_frac=args.fixed_k_frac, fixed_bits=args.fixed_bits,
        compress_b_min=args.b_range[0], compress_b_max=args.b_range[1],
        per_layer_budget=args.per_layer,
        staleness_family=args.staleness, staleness_alpha=args.staleness_alpha,
        scenario_backend=args.scenario_backend,
    )
    grid = ExperimentGrid(
        policies=tuple(args.policies.split(",")),
        mobility_models=tuple(args.mobility.split(",")),
        speeds=tuple(float(v) for v in args.speeds.split(",")),
        dropouts=tuple(float(d) for d in args.dropouts.split(",")),
        seeds=tuple(range(args.seeds)),
        rounds=args.rounds, eval_every=args.eval_every, base=base,
    )
    log.info("grid: %d cells (%d groups x %d seeds), arch=%s params=%d",
             grid.size(), len(grid.groups()), args.seeds, cfg.name,
             model.num_params())

    dev, ev = build_device_data(
        cfg, base, train_n=args.train_n, seq_len=args.seq_len, seed=0
    )
    shard = DataShard(dev, base.batch_size, seed=0)
    store = ResultsStore(args.out)
    mesh = make_seed_mesh(args.seeds)

    telemetry = None if args.no_telemetry else AFL_REGISTRY
    if telemetry is not None and (args.perdevice or args.probes):
        telemetry = TelemetrySuite(
            metrics=AFL_REGISTRY,
            device=DeviceTable(args.devices) if args.perdevice else None,
            probes=(TheoryProbes(s=model.num_params(), u=base.value_bits)
                    if args.probes else None),
        )
    tracer = PhaseTracer(profile_dir=args.profile_dir or None)
    tracer.start()
    sink = JsonlSink(os.path.join(args.out, "telemetry.jsonl"))
    try:
        table = run_sweep(grid, store, model, cfg, shard, ev, mesh=mesh,
                          telemetry=telemetry, tracer=tracer, sink=sink)
        sink.extend(tracer.events())
        if sink.events:  # a fully-resumed sweep must not blank the
            sink.flush()  # previous invocation's telemetry artifact
    finally:
        tracer.stop()
    print(table)
    if args.report:
        report_path = os.path.join(args.out, "report.md")
        with open(report_path, "w") as f:
            f.write(render_report(
                sink.events, title=f"Sweep report — {cfg.name}"))
        log.info("run report: %s", report_path)
    log.info("group wall clock:\n%s", tracer.summary())
    log.info("results under %s (cells/*.npz + results.jsonl + "
             "telemetry.jsonl)", args.out)


if __name__ == "__main__":
    main()
