"""Production meshes (functions, not module constants — importing this file
never touches jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis carries the cross-MES synchronisation in AFL training and extra batch
parallelism when serving.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older versions default to Auto axes anyway
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def force_host_device_count(n: int) -> None:
    """Simulate ``n`` host devices (CI meshes, parity suites, --mesh flags).

    Must run before the jax backend initialises (device count is fixed at
    first backend use).  Prefers the ``jax_num_cpu_devices`` config of
    newer jax; on older versions falls back to the
    ``--xla_force_host_platform_device_count`` XLA flag, which the lazily
    initialised backend still honours post-import.
    """
    import os

    try:
        jax.config.update("jax_num_cpu_devices", n)
        return
    except Exception:  # pragma: no cover - depends on installed jax
        pass
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    flags.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def make_client_mesh(num_clients: int):
    """(data, model) mesh for the distributed AFL step on host devices.

    The ``data`` axis (which carries the stacked client axis of
    ``core.distributed``) takes the largest device count dividing
    ``num_clients``; ``model`` stays 1 — CPU parity runs shard clients,
    not parameters.  Returns None on a single device.
    """
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    use = max(k for k in range(1, len(devs) + 1) if num_clients % k == 0)
    if use <= 1:
        return None
    return Mesh(np.asarray(devs[:use]).reshape(use, 1), ("data", "model"))


def make_seed_mesh(num_seeds: int):
    """1-D mesh for the experiment engine's seed axis (repro/experiments).

    Uses the largest device count that divides ``num_seeds`` so the vmapped
    seed axis shards evenly; returns None on a single device (the vmap
    alone is the batching there).
    """
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    use = max(k for k in range(1, len(devs) + 1) if num_seeds % k == 0)
    if use <= 1:
        return None
    return Mesh(np.asarray(devs[:use]), ("seed",))
