"""Production meshes (functions, not module constants — importing this file
never touches jax device state).

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis carries the cross-MES synchronisation in AFL training and extra batch
parallelism when serving.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older versions default to Auto axes anyway
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_seed_mesh(num_seeds: int):
    """1-D mesh for the experiment engine's seed axis (repro/experiments).

    Uses the largest device count that divides ``num_seeds`` so the vmapped
    seed axis shards evenly; returns None on a single device (the vmap
    alone is the batching there).
    """
    from jax.sharding import Mesh
    import numpy as np

    devs = jax.devices()
    use = max(k for k in range(1, len(devs) + 1) if num_seeds % k == 0)
    if use <= 1:
        return None
    return Mesh(np.asarray(devs[:use]), ("seed",))
