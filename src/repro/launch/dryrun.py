import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_DRYRUN_EXTRA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes, print memory/cost analysis, extract roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all                 # 16x16 sweep
  python -m repro.launch.dryrun --all --multi-pod     # 2x16x16 sweep
Results are appended as JSON lines to --out (default EXPERIMENTS-dryrun.jsonl)
and are the data source for EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step, resolve_cfg, supported  # noqa: E402
from repro.models.registry import N_IMG_PATCHES  # noqa: E402


def active_params(cfg, model) -> int:
    """Approximate activated parameters per token (MoE: routed top-k only)."""
    total = model.num_params()
    if not cfg.is_moe:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    routed_all = cfg.num_experts * per_expert
    routed_active = cfg.num_experts_per_tok * per_expert
    return total - cfg.num_layers * (routed_all - routed_active)


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_path: str,
            dist_overrides: dict | None = None, tag: str = "baseline",
            variant: str = "default", cfg_overrides: dict | None = None,
            dump_hlo: str | None = None) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    if cfg_overrides:
        cfg0 = cfg0.replace(**cfg_overrides)
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod, "tag": tag,
        "time": time.strftime("%Y-%m-%d %H:%M:%S"),
    }
    if not supported(cfg0, shape):
        rec.update(status="skipped", reason="long_500k unsupported (see DESIGN.md §4)")
        _append(out_path, rec)
        print(json.dumps(rec))
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        world = mesh.devices.size
        built = build_step(cfg0, shape, mesh, dist_overrides=dist_overrides,
                           variant=variant)
        cfg, model = built["cfg"], built["model"]
        with mesh:
            jitted = jax.jit(built["step"], in_shardings=built["in_shardings"])
            lowered = jitted.lower(*built["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(hlo)

        from repro.launch.calculator import step_analytics

        tokens = shape.global_batch * (
            shape.seq_len if shape.kind != "decode" else 1
        )
        mf = RL.model_flops(
            model.num_params(), tokens, active_params(cfg, model),
            train=(shape.kind == "train"),
        )
        mp = 1 if (variant == "dp_client" and shape.kind == "train") else 0
        analytic = step_analytics(cfg, shape, world, model.num_params(),
                                  model_parallel=mp)
        roof = RL.analyze(
            compiled, hlo, world, model_flops_total=mf, analytic=analytic,
            scan_trips=max(cfg.num_layers, 1),
        )

        rec.update(
            status="ok",
            world=world,
            num_params=model.num_params(),
            active_params=active_params(cfg, model),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            mem=dict(
                argument_gb=mem.argument_size_in_bytes / 1e9,
                output_gb=mem.output_size_in_bytes / 1e9,
                temp_gb=mem.temp_size_in_bytes / 1e9,
            ),
            roofline=roof.as_dict(),
        )
        print(
            f"[dryrun] {arch} x {shape_name} ({'2x16x16' if multi_pod else '16x16'}"
            f", {tag}): OK compile={t_compile:.0f}s "
            f"flops/dev={roof.flops:.3e} hbm/dev={roof.hbm_bytes:.3e} "
            f"coll/dev={roof.coll_bytes:.3e} bottleneck={roof.bottleneck} "
            f"temp={rec['mem']['temp_gb']:.1f}GB arg={rec['mem']['argument_gb']:.1f}GB"
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name}: FAIL {type(e).__name__}: {e}")
    _append(out_path, rec)
    return rec


def _append(path: str, rec: dict) -> None:
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (see configs)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true", help="sweep all arch x shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="EXPERIMENTS-dryrun.jsonl")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--variant", default="default", choices=["default", "dp_client"])
    ap.add_argument("--upload-dtype", default=None, help="e.g. bfloat16")
    ap.add_argument("--accum-dtype", default=None, help="e.g. bfloat16")
    ap.add_argument("--kv-cache-dtype", default=None, help="e.g. int8")
    ap.add_argument("--expert-dtype", default=None, help="e.g. int8")
    ap.add_argument("--remat", default=None, help="none|full|dots")
    ap.add_argument("--dump-hlo", default=None, help="write optimized HLO text here")
    args = ap.parse_args()

    dist_overrides = {}
    if args.upload_dtype:
        dist_overrides["upload_dtype"] = args.upload_dtype
    if args.accum_dtype:
        dist_overrides["accum_dtype"] = args.accum_dtype
    cfg_overrides = {}
    if args.kv_cache_dtype:
        cfg_overrides["kv_cache_dtype"] = args.kv_cache_dtype
    if args.expert_dtype:
        cfg_overrides["expert_dtype"] = args.expert_dtype
    if args.remat:
        cfg_overrides["remat"] = args.remat

    pairs = []
    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    if args.all:
        archs, shapes = list(ASSIGNED_ARCHS), list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for mp in meshes:
        for a, s in pairs:
            run_one(a, s, multi_pod=mp, out_path=args.out, tag=args.tag,
                    variant=args.variant, dist_overrides=dist_overrides or None,
                    cfg_overrides=cfg_overrides or None, dump_hlo=args.dump_hlo)


if __name__ == "__main__":
    main()
