"""Batched serving driver: prefill a batch of prompts, decode new tokens.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --batch 4 --prompt-len 64 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
      --batch 2 --prompt-len 128 --gen 8 --window 0

On CPU this runs the reduced variants end-to-end (greedy sampling); on TPU
the same code path uses the flash-decode / SSD Pallas kernels.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.utils import get_logger

log = get_logger("repro.serve")


def serve(cfg, model, params, prompts, gen: int, window: int = 0,
          frames=None):
    """Greedy generation: returns (tokens (B, gen), stats dict).

    ``frames``: encoder features for enc-dec (audio) archs, passed through
    to ``model.prefill`` — callers must NOT monkeypatch the model instance
    (a wrapped ``prefill`` survives into the next ``serve()`` call and
    injects stale frames).
    """
    if window and cfg.family in ("dense", "moe", "vlm"):
        cfg = cfg.replace(sliding_window=window)
    b, plen = prompts.shape
    max_seq = window or (plen + gen)
    fkw = {} if frames is None else {"frames": frames}
    t0 = time.time()
    if cfg.family == "ssm":
        last, cache = model.prefill(params, cfg, prompts, **fkw)
    else:
        last, cache = model.prefill(params, cfg, prompts, max_seq=max_seq,
                                    **fkw)
    # async dispatch: block before reading the clock or prefill time
    # under-counts and leaks into the decode measurement
    jax.block_until_ready(last)
    t_prefill = time.time() - t0

    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, cfg, c, t, pos)
    )
    out = []
    tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t0 = time.time()
    for i in range(gen):
        out.append(tok)
        logits, cache = decode(params, cache, tok, jnp.asarray(plen + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return jnp.stack(out, 1), {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": b * gen / max(t_decode, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true", default=False)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0, help="sliding window (ring cache)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("vision", "trajectory"):
        raise SystemExit("serve is for autoregressive archs")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    log.info("arch=%s params=%d batch=%d prompt=%d gen=%d",
             cfg.name, model.num_params(), args.batch, args.prompt_len, args.gen)
    frames = None
    if cfg.family == "audio":
        # enc-dec needs frames; pass stub features through serve()
        frames = jnp.asarray(
            rng.normal(0, 0.02, (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    toks, stats = serve(cfg, model, params, prompts, args.gen, args.window,
                        frames=frames)
    log.info("generated %s tokens; prefill=%.2fs decode=%.2fs (%.1f tok/s)",
             toks.shape, stats["prefill_s"], stats["decode_s"], stats["tok_per_s"])
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
