"""Step factories for every (arch x input-shape) combination.

For each shape kind this module builds (step_fn, example_args, in_shardings)
ready for ``jax.jit(...).lower(...)``:

* train_4k     -> the distributed AFL round (the paper's technique),
* prefill_32k  -> prompt pass returning (last logits, KV/recurrent cache),
* decode_32k   -> one-token decode against a seq_len cache,
* long_500k    -> one-token decode, sub-quadratic path (ring-buffer sliding
                  window for full-attention archs; native recurrent state for
                  SSM/hybrid).  Skipped for whisper (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.core.distributed import DistConfig, make_afl_train_system, mesh_num_clients
from repro.models.registry import Model, build_model, input_specs
from repro.sharding import rules as R

SLIDING_WINDOW = 8192  # ring-buffer size for long-context decode


def resolve_cfg(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-specific config tweaks (sliding-window for long_500k; remat on
    for training — without it the saved flash-scan carries are TBs/device)."""
    if (
        shape.name == "long_500k"
        and cfg.family in ("dense", "moe", "vlm")
        and cfg.sliding_window == 0
    ):
        cfg = cfg.replace(sliding_window=SLIDING_WINDOW)
    if shape.kind == "train" and cfg.remat == "none":
        cfg = cfg.replace(remat="full")
    return cfg


def supported(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def _input_shardings(dims_tree, shapes_tree_, rules, mesh):
    return jax.tree.map(
        lambda d, s: NamedSharding(mesh, R.logical_to_pspec(tuple(d), tuple(s.shape), rules, mesh)),
        dims_tree,
        shapes_tree_,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )


def _param_shardings(model: Model, rules, mesh):
    shapes = R.shapes_tree(model.specs)
    return R.sharding_tree(model.param_axes(), shapes, rules, mesh)


def _abstract_params(model: Model):
    shapes = R.shapes_tree(model.specs)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)


def cache_max_seq(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.family in ("ssm",):
        return 0
    if shape.name == "long_500k":
        return SLIDING_WINDOW
    return shape.seq_len


WARN_VARIANTS = ("default", "dp_client")

# dp_client (§Perf beyond-paper variant): replicate params, keep clients on
# (pod, data), and data-parallel each client's sequences over the `model`
# axis.  Removes ALL per-layer tensor-parallel activation collectives; what
# remains is one within-client gradient all-reduce + the AFL upload
# aggregation.  Right for small-d_model archs where 16-way TP is overkill.
RULES_TRAIN_DP = {
    "client": [("pod", "data"), ("data",)],
    "batch": [("pod", "data", "model"), ("data", "model")],
    **{k: [None] for k in (
        "layers", "vocab", "embed", "heads", "kv_heads", "head_dim", "mlp",
        "experts", "expert_mlp", "ssm_heads", "ssm_state", "ssm_inner",
        "conv", "seq", "pos",
    )},
}


def build_step(arch_cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               *, dist_overrides: dict | None = None,
               variant: str = "default"):
    """Returns dict(step, args, in_shardings, model, cfg)."""
    cfg = resolve_cfg(arch_cfg, shape)
    model = build_model(cfg)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        if variant == "dp_client":
            rules = RULES_TRAIN_DP
        else:
            rules = dict(R.RULES_TRAIN, client=[("pod", "data"), ("data",)])
        over = dist_overrides or {}
        dcfg = DistConfig(num_clients=mesh_num_clients(mesh), **over)
        sys_ = make_afl_train_system(model, cfg, mesh, dcfg, rules=rules)
        tree, dims = input_specs(cfg, shape)
        b_sh = _input_shardings(dims, tree, rules, mesh)
        n = dcfg.num_clients
        scal = jax.ShapeDtypeStruct((n,), jnp.float32)
        args = (sys_["abstract_state"](), tree, scal, scal, scal, scal)
        in_sh = (sys_["state_shardings"], b_sh, rep, rep, rep, rep)
        return dict(step=sys_["step"], args=args, in_shardings=in_sh,
                    model=model, cfg=cfg, system=sys_)

    rules = R.RULES_SERVE
    params = _abstract_params(model)
    p_sh = _param_shardings(model, rules, mesh)
    tree, dims = input_specs(cfg, shape)
    b_sh = _input_shardings(dims, tree, rules, mesh)

    if shape.kind == "prefill":
        if cfg.family == "vlm":
            from repro.models import transformer as T
            from repro.models import vlm as V

            def step(params, batch):
                emb = params["embed"]["tok"]
                text = emb[batch["tokens"]].astype(cfg.activation_dtype)
                x = jnp.concatenate(
                    [batch["vision_embeds"].astype(cfg.activation_dtype), text], axis=1
                )
                bsz, n_img = batch["vision_embeds"].shape[:2]
                grid = int(max(n_img, 1) ** 0.5) or 1
                pos = V.mrope_positions(bsz, n_img, batch["tokens"].shape[1], grid)
                return T.prefill(params, cfg, None, embeds=x, positions=pos)

        elif cfg.family == "audio":
            def step(params, batch):
                return model.prefill(params, cfg, batch["tokens"], frames=batch["frames"])

        else:
            def step(params, batch):
                return model.prefill(params, cfg, batch["tokens"])

        return dict(step=step, args=(params, tree), in_shardings=(p_sh, b_sh),
                    model=model, cfg=cfg)

    # decode
    max_seq = cache_max_seq(cfg, shape)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, shape.global_batch, max_seq))
    c_axes = model.cache_axes(cfg)
    c_sh = jax.tree.map(
        lambda d, s: NamedSharding(mesh, R.logical_to_pspec(tuple(d), tuple(s.shape), rules, mesh)),
        c_axes, cache,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(i, (str, type(None))) for i in x),
    )

    def step(params, cache, token, pos):
        return model.decode_step(params, cfg, cache, token, pos)

    args = (params, cache, tree["token"], tree["pos"])
    in_sh = (p_sh, c_sh, b_sh["token"], rep)
    return dict(step=step, args=args, in_shardings=in_sh, model=model, cfg=cfg)
