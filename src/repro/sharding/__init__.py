from repro.sharding.rules import (
    RULES_SERVE,
    RULES_TRAIN,
    ParamSpec,
    axes_tree,
    init_params,
    logical_to_pspec,
    pspec_tree,
    sharding_tree,
)

__all__ = [
    "RULES_SERVE",
    "RULES_TRAIN",
    "ParamSpec",
    "axes_tree",
    "init_params",
    "logical_to_pspec",
    "pspec_tree",
    "sharding_tree",
]
