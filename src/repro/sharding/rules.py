"""Logical-axis sharding rules with divisibility fallback.

Models annotate every parameter dimension with a *logical* axis name
(``embed``, ``heads``, ``mlp``, ``vocab``, ``experts``, ...).  Rule tables map
logical names to an ordered list of candidate mesh axes; the first candidate
that (a) exists in the mesh, (b) divides the dimension size, and (c) is not
already used by another dimension of the same tensor wins.  Dimensions with
no viable candidate stay unsharded.  This absorbs awkward arity (28 heads,
60 experts, kv_heads < model-parallelism) without per-arch special cases —
e.g. qwen2-7b's 4 kv heads fall back to sharding ``head_dim`` instead.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidates per logical axis. Tuples may name several mesh axes
# (sharded over their product). ``None`` = explicitly unsharded.
Rules = Mapping[str, Sequence[Optional[Tuple[str, ...]]]]

# --- Training (AFL distributed mode): ``data`` is the CLIENT axis ----------
RULES_TRAIN: Rules = {
    "client": [("data",)],
    "batch": [("pod", "data"), ("data",)],
    "layers": [None],
    "vocab": [("model",)],
    "embed": [None],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [("model",)],
    "mlp": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [("model",)],
    "ssm_heads": [("model",)],
    "ssm_state": [None],
    "ssm_inner": [("model",)],
    "conv": [None],
    "seq": [None],
    "pos": [None],
}

# --- Serving (prefill/decode): ``data`` shards batch (or cache sequence) ---
RULES_SERVE: Rules = {
    "client": [None],
    "batch": [("pod", "data"), ("data",), None],
    "layers": [None],
    "vocab": [("model",)],
    "embed": [None],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [("model",)],
    "mlp": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [("model",)],
    "ssm_heads": [("model",)],
    "ssm_state": [None],
    "ssm_inner": [("model",)],
    "conv": [None],
    "seq": [("data",), None],  # long-context KV cache: sequence-parallel
    "pos": [None],
}


def logical_to_pspec(
    dims: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Resolve one tensor's logical dims to a PartitionSpec."""
    assert len(dims) == len(shape), (dims, shape)
    used: set = set()
    out = []
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for name, size in zip(dims, shape):
        chosen = None
        for cand in rules.get(name or "", [None]):
            if cand is None:
                break
            if not all(a in axis_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= axis_sizes[a]
            if prod == 0 or size % prod != 0:
                continue
            chosen = cand
            break
        if chosen is None:
            out.append(None)
        else:
            used.update(chosen)
            out.append(chosen[0] if len(chosen) == 1 else chosen)
    # drop trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter specs: declarative model parameters with logical axes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ParamSpec:
    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Optional[str] = None  # override param dtype


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    dt = jnp.dtype(spec.dtype) if spec.dtype else dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, dt)
    if dt == jnp.int8:  # quantized weights: ints in [-127, 127]
        vals = jax.random.normal(key, spec.shape, jnp.float32) * 48.0
        return jnp.clip(jnp.round(vals), -127, 127).astype(jnp.int8)
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    if spec.init == "embed":
        std = 1.0
    elif spec.init == "small":
        std = 0.02
    else:
        std = (1.0 / max(fan_in, 1)) ** 0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * std * spec.scale).astype(dt)


def init_params(specs, rng, dtype=jnp.bfloat16):
    """Initialise a (nested dict) tree of ParamSpec into arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, max(len(leaves), 1))
    arrs = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def axes_tree(specs):
    """Extract the logical-dims pytree from a spec tree."""
    return jax.tree.map(
        lambda s: s.dims, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def shapes_tree(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype) if s.dtype else jnp.bfloat16),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def pspec_tree(axes, shapes, rules: Rules, mesh: Mesh):
    """Map a logical-dims tree + matching shape tree to PartitionSpecs."""
    return jax.tree.map(
        lambda d, s: logical_to_pspec(tuple(d), tuple(s.shape), rules, mesh),
        axes,
        shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )


def sharding_tree(axes, shapes, rules: Rules, mesh: Mesh):
    ps = pspec_tree(axes, shapes, rules, mesh)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        ps,
        is_leaf=lambda x: isinstance(x, P),
    )


def prepend_axis(axes, name: str):
    """Prepend a logical axis (e.g. ``client`` or ``layers``) to every leaf."""
    return jax.tree.map(
        lambda d: (name,) + tuple(d),
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, (str, type(None))) for i in x),
    )
