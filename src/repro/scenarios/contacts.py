"""Trace -> AFL round inputs: contact extraction and (zeta, tau) schedules.

Bridges the kinematics core to Algorithm 1: runs of in-range samples become
contact intervals, intervals become per-round (zeta, tau) via the same
first-writer-wins mapping the exponential model uses
(``repro.mobility.contact.intervals_to_rounds``), and per-round channel
gains come from the actual device-MES distances
(``repro.scenarios.channel.gains_along_trace``).
"""
from __future__ import annotations

import numpy as np

from repro.mobility.contact import intervals_to_rounds
from repro.scenarios.channel import gains_along_trace
from repro.scenarios.kinematics import Trace


def contact_intervals(in_range: np.ndarray, dt: float):
    """Extract contact intervals from a (steps, num_devices) bool trace.

    Returns flat arrays (dev, start, dur), ordered by device then time —
    the order ``intervals_to_rounds`` expects.  Contacts still open at the
    end of the trace are censored at the observation window.
    """
    steps, n = in_range.shape
    padded = np.zeros((n, steps + 2), bool)
    padded[:, 1:-1] = in_range.T
    d = np.diff(padded.astype(np.int8), axis=1)
    starts = np.argwhere(d == 1)  # row-major -> sorted by (device, time)
    ends = np.argwhere(d == -1)  # same count per device, aligned pairwise
    dev = starts[:, 0]
    start = starts[:, 1] * dt
    dur = (ends[:, 1] - starts[:, 1]) * dt
    return dev, start, dur


def rounds_from_trace(trace: Trace, comm_range: float, rounds: int,
                      round_duration: float, channel=None,
                      shadow_corr_dist: float = 25.0, rng=None):
    """(zeta, tau, h2) for ``rounds`` rounds of duration ``round_duration``.

    zeta/tau follow the exponential model's semantics (full contact duration
    at the contact-start round, remaining duration in continuation rounds).
    h2 is position-coupled when a ``WirelessChannel`` is passed: path loss +
    correlated shadowing at the device-MES distance sampled at each round
    start (None otherwise).
    """
    n = trace.num_devices
    dev, start, dur = contact_intervals(trace.in_range(comm_range), trace.dt)
    zeta, tau = intervals_to_rounds(dev, start, dur, n, rounds, round_duration)

    h2 = None
    if channel is not None:
        # per-round sample index (NOT a constant integer stride: that drifts
        # linearly whenever round_duration is not a multiple of dt)
        ridx = np.minimum(
            (np.arange(rounds) * (round_duration / trace.dt)).astype(np.int64),
            trace.steps - 1,
        )
        h2 = gains_along_trace(
            channel, trace.pos[ridx], trace.mes[ridx],
            shadow_corr_dist=shadow_corr_dist, rng=rng,
        )
    return zeta, tau, h2
