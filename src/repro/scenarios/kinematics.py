"""Vectorized mobility kinematics — the scenario engine's motion core.

Four models behind one ``MobilityModel`` protocol, all NumPy-batched over
devices (no per-device Python loops; the only remaining loops are either
over *legs* via interpolation — O(devices) searchsorted calls — or a cheap
O(steps) AR(1) recurrence on (N, 2) vectors):

* ``RandomWaypointModel``  — leg-based vectorized port of the seed
  ``repro.mobility.waypoint.RandomWaypoint``: waypoint legs are sampled up
  front for every device, then positions at all query times come from a
  piecewise-linear interpolation (searchsorted over leg start times).
* ``GaussMarkovModel``     — AR(1) velocity process with reflecting walls.
  Parametrised by a velocity *decorrelation distance* so the trajectory
  statistics are an exact time-rescaling in mean speed (the paper's
  c = C/v, lambda = L/v inverse-speed law holds by construction).
* ``ManhattanGridModel``   — vehicular grid mobility: devices travel along
  streets of a ``block``-spaced lattice, turning at intersections via an
  i.i.d. turn sequence (straight / left / right), folded back into the
  area by reflection (lattice-preserving since block | area).
* ``HotspotClusterModel``  — devices anchored to hotspot centres, wandering
  around them by an Ornstein-Uhlenbeck excursion whose time constant is
  ``hotspot_radius / mean_speed`` (static scenario at mean_speed = 0).

Every model returns a ``Trace`` (positions for all steps + the MES
position), from which ``repro.scenarios.contacts`` derives per-round
``(zeta, tau)`` and ``repro.scenarios.channel`` derives position-coupled
``h2``.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass
class Trace:
    """Device + MES positions sampled on a uniform time grid."""

    pos: np.ndarray  # (steps, num_devices, 2) metres
    mes: np.ndarray  # (steps, 2) MES position
    dt: float  # seconds between samples

    @property
    def steps(self) -> int:
        return self.pos.shape[0]

    @property
    def num_devices(self) -> int:
        return self.pos.shape[1]

    def distances(self) -> np.ndarray:
        """(steps, num_devices) device-MES distance."""
        return np.linalg.norm(self.pos - self.mes[:, None, :], axis=-1)

    def in_range(self, comm_range: float) -> np.ndarray:
        """(steps, num_devices) bool contact indicator."""
        return self.distances() < comm_range


@runtime_checkable
class MobilityModel(Protocol):
    """Anything that can simulate device motion for a duration."""

    num_devices: int
    area: float
    mean_speed: float

    def trace(self, duration: float, dt: float = 1.0) -> Trace: ...


def _reflect(x: np.ndarray, hi: float) -> np.ndarray:
    """Fold unbounded coordinates into [0, hi] by reflection at the walls."""
    y = np.mod(x, 2.0 * hi)
    return np.where(y > hi, 2.0 * hi - y, y)


def _static_mes(steps: int, area: float) -> np.ndarray:
    return np.full((steps, 2), 0.5 * area)


def _interp_legs(tq, leg_start, travel, nodes):
    """Piecewise-linear positions for ALL entities' waypoint legs at once.

    leg_start (n, m): departure time of each leg; travel (n, m): moving time
    of each leg (arrival at leg_start + travel, then idle until the next
    leg); nodes (n, m+1, 2): leg endpoints.  Returns (len(tq), n, 2).

    Each leg becomes two breakpoints — (depart, node_k) and
    (depart + travel, node_{k+1}) — so np.interp renders both the motion
    and the pause (a flat segment) in one C-level pass per entity, with no
    steps x entities temporaries.
    """
    n, m = travel.shape
    tp = np.empty((n, 2 * m))
    tp[:, 0::2] = leg_start
    tp[:, 1::2] = leg_start + travel
    xs = np.empty((n, 2 * m, 2))
    xs[:, 0::2] = nodes[:, :-1]
    xs[:, 1::2] = nodes[:, 1:]
    pos = np.empty((len(tq), n, 2), np.float32)
    for i in range(n):  # C-speed interp per entity; no batched temporaries
        pos[:, i, 0] = np.interp(tq, tp[i], xs[i, :, 0])
        pos[:, i, 1] = np.interp(tq, tp[i], xs[i, :, 1])
    return pos


# ---------------------------------------------------------------------------
# Random waypoint (vectorized port of repro.mobility.waypoint.RandomWaypoint)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RandomWaypointModel:
    num_devices: int = 20
    area: float = 1000.0  # m (square side)
    mean_speed: float = 10.0  # m/s; per-leg speeds ~ U(0.5v, 1.5v)
    pause_max: float = 5.0  # s pause at each waypoint
    mobile_mes: bool = False  # seed parity: entity 0 (the MES) also moves
    seed: int = 0

    def trace(self, duration: float, dt: float = 1.0) -> Trace:
        rng = np.random.default_rng(self.seed)
        steps = int(duration / dt)
        tq = np.arange(steps) * dt
        n_ent = self.num_devices + (1 if self.mobile_mes else 0)

        # generous leg budget: mean leg = mean travel + mean pause, with the
        # expected distance between two uniform points in a square = .5214 a
        est_leg = 0.5214 * self.area / self.mean_speed + 0.5 * self.pause_max
        m = int(duration / max(est_leg, 1e-9) * 1.8) + 8
        while True:
            nodes = rng.uniform(0, self.area, (n_ent, m + 1, 2))
            speeds = rng.uniform(
                0.5 * self.mean_speed, 1.5 * self.mean_speed, (n_ent, m)
            )
            pauses = rng.uniform(0, self.pause_max, (n_ent, m))
            travel = (
                np.linalg.norm(np.diff(nodes, axis=1), axis=-1)
                / np.maximum(speeds, 1e-9)
            )
            leg_start = np.zeros((n_ent, m + 1))
            leg_start[:, 1:] = np.cumsum(travel + pauses, axis=1)
            if leg_start[:, -1].min() >= duration:
                break
            m *= 2  # rare: a device drew unusually short legs

        pos = _interp_legs(tq, leg_start[:, :-1], travel, nodes)
        if self.mobile_mes:
            return Trace(pos=pos[:, 1:], mes=pos[:, 0], dt=dt)
        return Trace(pos=pos, mes=_static_mes(steps, self.area), dt=dt)


# ---------------------------------------------------------------------------
# Gauss-Markov
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GaussMarkovModel:
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0  # E|v|
    corr_dist: float = 200.0  # m travelled before velocity decorrelates
    seed: int = 0

    def trace(self, duration: float, dt: float = 1.0) -> Trace:
        rng = np.random.default_rng(self.seed)
        steps = int(duration / dt)
        n = self.num_devices
        # alpha = exp(-v dt / d_corr): the memory time is d_corr / v, so the
        # whole process is a time-rescaling in mean_speed (inverse-speed law)
        alpha = np.exp(-dt * self.mean_speed / max(self.corr_dist, 1e-9))
        sig_c = self.mean_speed / np.sqrt(np.pi / 2.0)  # E|v| = sig_c sqrt(pi/2)
        scale = sig_c * np.sqrt(max(1.0 - alpha * alpha, 0.0))

        noise = rng.normal(0.0, 1.0, (steps, n, 2))
        v = np.empty((steps, n, 2))
        prev = rng.normal(0.0, sig_c, (n, 2))
        for t in range(steps):  # O(steps) recurrence on (n, 2) vectors
            prev = alpha * prev + scale * noise[t]
            v[t] = prev
        x0 = rng.uniform(0, self.area, (n, 2))
        pos = _reflect(x0[None] + np.cumsum(v, axis=0) * dt, self.area)
        return Trace(pos=pos, mes=_static_mes(steps, self.area), dt=dt)


# ---------------------------------------------------------------------------
# Manhattan grid (vehicular)
# ---------------------------------------------------------------------------

_DIRS = np.array([[1, 0], [0, 1], [-1, 0], [0, -1]], np.float64)


@dataclasses.dataclass
class ManhattanGridModel:
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0  # per-device speeds ~ U(0.5v, 1.5v), constant
    block: float = 100.0  # m street spacing
    p_turn: float = 0.5  # turn probability at an intersection (split L/R)
    seed: int = 0

    def trace(self, duration: float, dt: float = 1.0) -> Trace:
        rng = np.random.default_rng(self.seed)
        steps = int(duration / dt)
        n = self.num_devices
        grid_n = max(int(round(self.area / self.block)), 1)
        a = grid_n * self.block  # snap area to a whole number of blocks

        speeds = rng.uniform(0.5 * self.mean_speed, 1.5 * self.mean_speed, n)
        speeds = np.maximum(speeds, 1e-9)
        m = int(duration * speeds.max() / self.block) + 2

        # i.i.d. turns -> heading per leg by cumulative rotation (mod 4)
        u = rng.random((n, m))
        turn = np.where(u < 0.5 * self.p_turn, 1, np.where(u < self.p_turn, -1, 0))
        head0 = rng.integers(0, 4, n)
        head = (head0[:, None] + np.concatenate(
            [np.zeros((n, 1), np.int64), np.cumsum(turn, axis=1)[:, :-1]], axis=1
        )) % 4
        start = rng.integers(0, grid_n + 1, (n, 2)) * self.block
        nodes = start[:, None, :] + self.block * np.concatenate(
            [np.zeros((n, 1, 2)), np.cumsum(_DIRS[head], axis=1)], axis=1
        )
        # reflection folds lattice points onto lattice points (block | area),
        # so interpolated positions always stay on a street
        nodes = _reflect(nodes, a)

        # constant leg duration per device -> leg index is a direct divide
        leg_dur = self.block / speeds  # (n,)
        tq = np.arange(steps) * dt
        idx = np.clip((tq[None, :] / leg_dur[:, None]).astype(np.int64), 0, m - 1)
        frac = np.clip(
            tq[None, :] / leg_dur[:, None] - idx, 0.0, 1.0
        )
        gather = np.broadcast_to(idx[:, :, None], (n, steps, 2))
        p0 = np.take_along_axis(nodes, gather, axis=1)
        p1 = np.take_along_axis(nodes, gather + 1, axis=1)
        pos = (p0 + frac[:, :, None] * (p1 - p0)).transpose(1, 0, 2)
        return Trace(pos=pos, mes=_static_mes(steps, a), dt=dt)


# ---------------------------------------------------------------------------
# Hotspot clusters (quasi-static crowds)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HotspotClusterModel:
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0  # 0 -> perfectly static devices
    num_hotspots: int = 4
    hotspot_radius: float = 150.0  # RMS excursion around the anchor
    seed: int = 0

    def trace(self, duration: float, dt: float = 1.0) -> Trace:
        rng = np.random.default_rng(self.seed)
        steps = int(duration / dt)
        n = self.num_devices
        centers = rng.uniform(0.15 * self.area, 0.85 * self.area,
                              (self.num_hotspots, 2))
        anchor = centers[rng.integers(0, self.num_hotspots, n)]

        sig_c = self.hotspot_radius / np.sqrt(2.0)  # per-axis -> RMS = radius
        if self.mean_speed <= 0:  # static scenario
            off = rng.normal(0.0, sig_c, (n, 2))
            pos = np.broadcast_to(
                np.clip(anchor + off, 0.0, self.area), (steps, n, 2)
            ).copy()
            return Trace(pos=pos, mes=_static_mes(steps, self.area), dt=dt)

        # smooth wander around the anchor: Gauss-Markov VELOCITY with a
        # restoring drift toward the hotspot centre.  A velocity-level (not
        # position-level) noise keeps sample paths differentiable, so range
        # crossings have macroscopic duration and the whole process is a
        # time-rescaling in mean_speed (inverse-speed law).
        radius = max(self.hotspot_radius, 1e-9)
        rate = self.mean_speed / radius  # 1/s relaxation
        alpha = np.exp(-dt * rate)
        vel_sig = self.mean_speed / np.sqrt(np.pi / 2.0)
        scale = vel_sig * np.sqrt(max(1.0 - alpha * alpha, 0.0))
        noise = rng.normal(0.0, 1.0, (steps, n, 2))
        pos = np.empty((steps, n, 2))
        off = rng.normal(0.0, sig_c, (n, 2))
        vel = rng.normal(0.0, vel_sig, (n, 2))
        for t in range(steps):  # O(steps) recurrence on (n, 2) vectors
            vel = alpha * vel - (1.0 - alpha) * rate * off + scale * noise[t]
            off = off + vel * dt
            pos[t] = anchor + off
        pos = np.clip(pos, 0.0, self.area)
        return Trace(pos=pos, mes=_static_mes(steps, self.area), dt=dt)
