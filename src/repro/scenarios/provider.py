"""ScenarioProvider — streaming (zeta, tau, h2) round inputs for AFL.

One object owns the whole scenario: a mobility model (or the paper's
exponential renewal abstraction), the contact extractor, and the
position-coupled channel.  ``from_config(fl)`` reads everything from the
``FLConfig`` scenario fields; the full rounds x N schedule is precomputed
on first access (three rounds x N arrays: ~1 MB at the paper's scale,
~120 MB at rounds=10k, N=1k) and then streamed per round to
``core/runner.py`` or the distributed ``make_afl_train_step`` path.

    provider = ScenarioProvider.from_config(fl, rounds)
    for zeta_r, tau_r, h2_r in provider: ...   # or provider.round(r)
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional, Tuple

import numpy as np

from repro.channel.wireless import WirelessChannel
from repro.mobility.contact import ContactProcess
from repro.scenarios.contacts import rounds_from_trace
from repro.scenarios.heterogeneity import HeterogeneityModel
from repro.scenarios.kinematics import (
    GaussMarkovModel,
    HotspotClusterModel,
    ManhattanGridModel,
    MobilityModel,
    RandomWaypointModel,
)

Schedule = Tuple[np.ndarray, np.ndarray, np.ndarray]

MODELS = {
    "rwp": RandomWaypointModel,
    "gauss_markov": GaussMarkovModel,
    "manhattan": ManhattanGridModel,
    "hotspot": HotspotClusterModel,
}


def _channel_from_config(fl, seed: int) -> WirelessChannel:
    return WirelessChannel(
        bandwidth=fl.bandwidth, carrier_ghz=fl.carrier_ghz,
        noise_dbm_hz=fl.noise_dbm_hz, seed=seed,
    )


def model_from_config(fl, seed: Optional[int] = None) -> MobilityModel:
    """Build the FLConfig-selected kinematic model (trace models only).

    ``fl.speed = 0`` is the legacy "unset" sentinel and maps to 10 m/s for
    the moving models; use ``mobility_model="static"`` for motionless
    hotspot crowds.
    """
    seed = fl.seed if seed is None else seed
    name = fl.mobility_model
    speed = fl.speed if fl.speed > 0 else 10.0
    common = dict(num_devices=fl.num_devices, area=fl.area, mean_speed=speed,
                  seed=seed)
    if name == "rwp":
        return RandomWaypointModel(pause_max=fl.pause_max, **common)
    if name == "gauss_markov":
        return GaussMarkovModel(corr_dist=fl.gm_corr_dist, **common)
    if name == "manhattan":
        return ManhattanGridModel(block=fl.street_block, **common)
    if name in ("hotspot", "static"):
        if name == "static":
            common["mean_speed"] = 0.0
        return HotspotClusterModel(
            num_hotspots=fl.num_hotspots, hotspot_radius=fl.hotspot_radius,
            **common,
        )
    raise KeyError(f"unknown mobility model {name!r}; known: "
                   f"exponential, static, {sorted(MODELS)}")


def jax_model_from_config(fl, seed: Optional[int] = None):
    """The device-resident twin of ``model_from_config`` (jax_kinematics).

    Same FLConfig fields, same speed sentinel; returns a frozen (hashable)
    JAX model usable as a jit static arg.
    """
    from repro.scenarios.jax_kinematics import (
        JaxGaussMarkovModel,
        JaxHotspotClusterModel,
        JaxManhattanGridModel,
        JaxRandomWaypointModel,
    )

    seed = fl.seed if seed is None else seed
    name = fl.mobility_model
    speed = fl.speed if fl.speed > 0 else 10.0
    common = dict(num_devices=fl.num_devices, area=fl.area, mean_speed=speed,
                  seed=seed)
    if name == "rwp":
        return JaxRandomWaypointModel(pause_max=fl.pause_max, **common)
    if name == "gauss_markov":
        return JaxGaussMarkovModel(corr_dist=fl.gm_corr_dist, **common)
    if name == "manhattan":
        return JaxManhattanGridModel(block=fl.street_block, **common)
    if name in ("hotspot", "static"):
        if name == "static":
            common["mean_speed"] = 0.0
        return JaxHotspotClusterModel(
            num_hotspots=fl.num_hotspots, hotspot_radius=fl.hotspot_radius,
            **common,
        )
    raise KeyError(f"unknown mobility model {name!r} for the jax backend; "
                   f"known: static, {sorted(MODELS)}")


class ScenarioProvider:
    """Streams per-round (zeta, tau, h2); precomputes the schedule lazily.

    With a ``HeterogeneityModel`` attached (``fl.het_*`` knobs), the built
    schedule is gated once — effective window = contact ∩ available, minus
    compute time, minus dropout — and the per-round loss masks are exposed
    as ``aux`` / ``aux_round`` for the telemetry ``DeviceTable``.
    """

    def __init__(self, rounds: int, num_devices: int,
                 build: Optional[Callable[[], Schedule]] = None,
                 schedule: Optional[Schedule] = None,
                 het: Optional[HeterogeneityModel] = None):
        self.rounds = rounds
        self.num_devices = num_devices
        self._build = build
        self._schedule = schedule
        self._het = het if (het is not None and het.enabled()) else None
        self._aux = None

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_config(cls, fl, rounds: Optional[int] = None,
                    seed: Optional[int] = None) -> "ScenarioProvider":
        """Scenario selected by ``fl.mobility_model``.

        ``"exponential"`` reproduces the paper's renewal abstraction (and the
        legacy ``contact_schedule`` distribution) with i.i.d. channel gains;
        the trace models derive (zeta, tau) from simulated motion and h2
        from the actual device-MES distances.
        """
        rounds = fl.rounds if rounds is None else rounds
        seed = fl.seed if seed is None else seed
        chan = _channel_from_config(fl, seed + 1)
        het = HeterogeneityModel.from_config(fl, seed + 2)

        backend = getattr(fl, "scenario_backend", "numpy")
        if backend not in ("numpy", "jax"):
            raise KeyError(f"unknown scenario backend {backend!r}; "
                           "known: numpy, jax")
        # the renewal abstraction has no kinematics to port: it always
        # builds host-side (already O(rounds x N) vectorized)
        if backend == "jax" and fl.mobility_model != "exponential":
            from repro.scenarios.jax_kinematics import jax_schedule_from_model

            # the frozen model is a jit static arg: keep its seed field
            # canonical and feed the actual seed through the PRNG key, so
            # every seed of a sweep reuses ONE compiled scenario program
            model = jax_model_from_config(fl, 0)

            def build() -> Schedule:
                return jax_schedule_from_model(
                    model, rounds, fl.round_duration, dt=fl.mobility_dt,
                    comm_range=fl.comm_range,
                    shadow_corr_dist=fl.shadow_corr_dist,
                    carrier_ghz=fl.carrier_ghz, seed=seed,
                )

            return cls(rounds, fl.num_devices, build=build, het=het)

        if fl.mobility_model == "exponential":
            def build() -> Schedule:
                if fl.speed > 0:
                    proc = ContactProcess.from_speed(
                        fl.num_devices, fl.speed, fl.contact_const,
                        fl.intercontact_const, fl.round_duration, seed,
                    )
                else:
                    proc = ContactProcess(
                        fl.num_devices, fl.mean_contact, fl.mean_intercontact,
                        fl.round_duration, seed,
                    )
                zeta, tau = proc.sample_rounds(rounds)
                # no positions in the renewal abstraction: i.i.d. gains as in
                # the seed runner
                h2 = chan.sample_gain((rounds, fl.num_devices))
                return zeta, tau, h2.astype(np.float32)
        else:
            model = model_from_config(fl, seed)

            def build() -> Schedule:
                trace = model.trace(rounds * fl.round_duration, fl.mobility_dt)
                zeta, tau, h2 = rounds_from_trace(
                    trace, fl.comm_range, rounds, fl.round_duration,
                    channel=chan, shadow_corr_dist=fl.shadow_corr_dist,
                    rng=np.random.default_rng(seed + 1),
                )
                return zeta, tau, h2.astype(np.float32)

        return cls(rounds, fl.num_devices, build=build, het=het)

    @classmethod
    def from_arrays(cls, zeta: np.ndarray, tau: np.ndarray,
                    h2: Optional[np.ndarray] = None,
                    channel: Optional[WirelessChannel] = None,
                    seed: int = 0) -> "ScenarioProvider":
        """Wrap a precomputed (zeta, tau) schedule (legacy / transformed).

        Without h2, gains are sampled i.i.d. from ``channel`` (or a default
        ``WirelessChannel``) — the seed runner's behaviour.
        """
        zeta = np.asarray(zeta)
        rounds, n = zeta.shape
        if h2 is None:
            channel = channel or WirelessChannel(seed=seed)
            h2 = channel.sample_gain((rounds, n))
        return cls(rounds, n, schedule=(
            zeta, np.asarray(tau, np.float32), np.asarray(h2, np.float32)
        ))

    @classmethod
    def from_model(cls, model: MobilityModel, rounds: int,
                   round_duration: float, comm_range: float = 100.0,
                   channel: Optional[WirelessChannel] = None,
                   dt: float = 1.0, shadow_corr_dist: float = 25.0,
                   seed: int = 0) -> "ScenarioProvider":
        """Scenario from an explicit kinematic model (tests / notebooks)."""
        channel = channel or WirelessChannel(seed=seed + 1)

        def build() -> Schedule:
            trace = model.trace(rounds * round_duration, dt)
            zeta, tau, h2 = rounds_from_trace(
                trace, comm_range, rounds, round_duration, channel=channel,
                shadow_corr_dist=shadow_corr_dist,
                rng=np.random.default_rng(seed + 1),
            )
            return zeta, tau, h2.astype(np.float32)

        return cls(rounds, model.num_devices, build=build)

    # -- access -------------------------------------------------------------

    def prefetch(self) -> "ScenarioProvider":
        """Force schedule materialisation now (otherwise lazy)."""
        self.schedule()
        return self

    def schedule(self) -> Schedule:
        """The full (zeta, tau, h2) arrays, each (rounds, num_devices)."""
        if self._schedule is None:
            zeta, tau, h2 = self._build()
            if self._het is not None:
                if isinstance(zeta, np.ndarray):
                    zeta, tau, self._aux = self._het.apply(zeta, tau)
                else:  # device-resident schedule: gate without leaving device
                    from repro.scenarios.heterogeneity import jax_apply

                    zeta, tau, self._aux = jax_apply(self._het, zeta, tau)
            self._schedule = (zeta, tau, h2)
        return self._schedule

    @property
    def aux(self):
        """Heterogeneity loss masks {"unavail", "dropout"}, each
        (rounds, N), or None when the layer is disabled."""
        self.schedule()
        return self._aux

    def aux_round(self, r: int):
        """Round r's slice of ``aux`` (None when disabled)."""
        aux = self.aux
        return None if aux is None else {k: v[r] for k, v in aux.items()}

    def round(self, r: int) -> Schedule:
        """(zeta_r, tau_r, h2_r) for round r, each (num_devices,)."""
        zeta, tau, h2 = self.schedule()
        return zeta[r], tau[r], h2[r]

    def __iter__(self) -> Iterator[Schedule]:
        zeta, tau, h2 = self.schedule()
        for r in range(self.rounds):
            yield zeta[r], tau[r], h2[r]

    def __len__(self) -> int:
        return self.rounds
