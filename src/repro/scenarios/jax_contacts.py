"""JAX contact extraction: in-range runs -> intervals -> per-round (zeta, tau).

The device-resident port of ``scenarios/contacts.py`` +
``mobility.contact.intervals_to_rounds``.  On the SAME (steps, N) in-range
matrix it is exactly equal to the NumPy pair — same first-writer-wins
round claiming, same tau semantics (full contact duration at the
contact-start round, remaining duration from the round boundary in
continuation rounds), same end-of-trace censoring — which is what the
differential harness (tests/test_jax_scenarios.py) pins down cell by
cell.  The kinematic *inputs* differ across backends (independent PRNGs),
so end-to-end schedules agree statistically, not bitwise.

The extraction is scatter-free and shape-static, built from three
O(steps x N) prefix scans:

* ``start_idx[t]`` — running cummax of start-flag positions: the start
  step of the contact run covering t;
* ``end_idx[t]``   — reversed cummin of out-of-range positions: the
  first out-of-range step at/after t (``steps`` when the run reaches the
  trace end — the censored/truncated case);
* ``nxt[t]``       — reversed cummin of in-range positions: the first
  in-range step at/after t.

A round r spans step indices [t_lo, t_hi]; the earliest interval
overlapping it is the run of ``nxt[t_lo]``, and one gather per (round,
device) cell yields zeta/tau.  ``drop_truncated`` masks cells claimed by
a run still open at the trace end — the same window-bias fix PR 1 gave
``measure_contact_stats`` (truncated contacts bias mean contact time low
and contact rate high; at trace horizons ~ tens of mean contact times
the bias is visible in CI-band tests).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["contact_intervals_jax", "rounds_from_in_range", "run_bounds"]


def run_bounds(in_range):
    """(start_idx, end_idx, nxt) prefix-scan tables for a (steps, N) bool
    in-range matrix; see the module docstring for their semantics.
    Valid wherever they are gathered below; ``steps`` is the sentinel."""
    steps = in_range.shape[0]
    ir = in_range
    idx = jnp.arange(steps, dtype=jnp.int32)[:, None]
    prev = jnp.pad(ir[:-1], ((1, 0), (0, 0)))
    start_flag = ir & ~prev
    start_idx = jax.lax.cummax(jnp.where(start_flag, idx, -1), axis=0)
    rev = lambda x: jnp.flip(jax.lax.cummin(jnp.flip(x, 0), axis=0), 0)
    end_idx = rev(jnp.where(~ir, idx, steps))
    nxt = rev(jnp.where(ir, idx, steps))
    return start_idx, end_idx, nxt


def contact_intervals_jax(in_range, dt: float, size=None):
    """Flat (dev, start, dur) contact intervals — device-resident twin of
    ``scenarios.contacts.contact_intervals``, same device-then-time order.

    Contacts still open at the trace end are censored at the window,
    exactly like the oracle.  Without ``size`` the call is host-synced
    (dynamic result count — fine for tests/notebooks); pass a static
    ``size`` to keep it jittable, and the result is padded with -1 device
    ids beyond the true interval count.
    """
    ir = jnp.asarray(in_range, bool)
    steps, n = ir.shape
    _, end_idx, _ = run_bounds(ir)
    prev = jnp.pad(ir[:-1], ((1, 0), (0, 0)))
    start_flag = (ir & ~prev).T.reshape(-1)  # (n*steps): device-major
    flat = jnp.nonzero(start_flag, size=size, fill_value=-1)[0] \
        if size is not None else jnp.nonzero(start_flag)[0]
    dev = flat // steps
    t = flat % steps
    ok = flat >= 0
    e = end_idx[t, jnp.clip(dev, 0)]
    return (jnp.where(ok, dev, -1),
            jnp.where(ok, t, 0).astype(jnp.float32) * dt,
            jnp.where(ok, (e - t).astype(jnp.float32) * dt, 0.0))


@partial(jax.jit, static_argnames=("dt", "rounds", "delta",
                                   "drop_truncated"))
def rounds_from_in_range(in_range, dt: float, rounds: int, delta: float,
                         drop_truncated: bool = False):
    """(zeta, tau) per round from a (steps, N) in-range matrix, exactly
    matching ``contact_intervals`` + ``intervals_to_rounds`` cell-wise.

    Returns ((rounds, N) int32, (rounds, N) float32).  ``drop_truncated``
    zeroes every cell claimed by a contact still open at the trace end —
    the extractor-level mirror of ``measure_contact_stats``'s
    ``drop_truncated`` (a censored contact's tau under-states the real
    window; biased cells poison contact-time statistics at short
    horizons).  The oracle pair has no such switch: the regression test
    drops trailing intervals host-side to cross-check.
    """
    ir = jnp.asarray(in_range, bool)
    steps, n = ir.shape
    start_idx, end_idx, nxt = run_bounds(ir)

    # static per-round step windows: round r covers [t_lo, t_hi].  A run
    # [S, E) overlaps round r iff S <= t_hi and E - 1 >= t_lo, and two
    # intersecting contiguous index ranges always share a step, so the
    # earliest overlapping run is the run of the first in-range step in
    # the window: nxt[t_lo].
    r = np.arange(rounds)
    t_lo = np.floor(r * delta / dt).astype(np.int64)
    t_hi = np.minimum(np.ceil((r + 1) * delta / dt).astype(np.int64) - 1,
                      steps - 1)
    in_window = t_lo < steps  # horizon guard (non-integer delta/dt grids)
    t_lo = np.minimum(t_lo, steps - 1)

    tstar = nxt[t_lo]  # (rounds, n): first in-range step in the window
    valid = (tstar <= jnp.asarray(t_hi)[:, None]) \
        & jnp.asarray(in_window)[:, None]
    tc = jnp.clip(tstar, 0, steps - 1)
    gidx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None],
                            tc.shape)
    s_idx = start_idx[tc, gidx]
    e_idx = end_idx[tc, gidx]
    truncated = e_idx == steps  # run reaches the trace end (censored)

    s = s_idx.astype(jnp.float32) * dt
    e = e_idx.astype(jnp.float32) * dt
    r0 = jnp.floor(s / delta).astype(jnp.int32)
    rr = jnp.arange(rounds, dtype=jnp.int32)[:, None]
    tau_cand = jnp.where(r0 == rr, e - s, e - rr.astype(jnp.float32) * delta)

    if drop_truncated:
        valid = valid & ~truncated
    zeta = valid.astype(jnp.int32)
    tau = jnp.where(valid, tau_cand, 0.0).astype(jnp.float32)
    return zeta, tau
