"""Per-client system heterogeneity: availability, compute latency, dropout.

The FLGo-style ``system_simulator`` layer (and the edge-vehicular AFL
setting of arxiv 2208.01901) composed with the mobility contact windows:
a contact only becomes an upload opportunity when the client is
*available* (a two-state Markov chain), the window that remains after
local compute is positive (effective window = contact ∩ available, minus
compute time), and the upload is not lost to a random dropout.  The
layer is a pure schedule rewrite — (zeta, tau) in, gated (zeta', tau')
out plus per-round aux masks — so every engine (loop, scan, pjit)
consumes heterogeneous scenarios without touching its compiled round;
the aux masks ride the telemetry ``DeviceTable`` as ``unavail`` /
``dropouts`` counters (``repro.telemetry.record_het``).

Availability chain: per round, an available client stays available with
probability ``rho + (1 - rho) * pi`` and an unavailable one recovers
with ``(1 - rho) * pi`` — stationary distribution P(available) = ``pi``
(= ``availability``) for any persistence ``rho`` (= ``avail_persist``),
which the unit tests assert empirically.  Compute latency is Exp(mean
``compute_mean``) per (round, client) — the memoryless stand-in for
heterogeneous device speeds; dropout is i.i.d. Bernoulli(``dropout``)
over otherwise-successful uploads.

Both backends share one gating rule (``gate_windows`` — plain arithmetic,
np or jnp operands): NumPy ``apply`` is the oracle, ``jax_apply`` the
device-resident twin (statistical parity; exact parity on shared draws).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HeterogeneityModel",
    "gate_windows",
    "jax_apply",
    "reference_apply",
]

#: aux-mask keys the telemetry DeviceTable accumulates (record_het)
HET_COUNTER_KEYS = ("unavail", "dropout")


def gate_windows(zeta, tau, avail, latency, drop):
    """The single gating rule both backends apply to fixed draws.

    zeta/tau: (R, N) contact schedule; avail: (R, N) availability states;
    latency: (R, N) compute-latency draws (s); drop: (R, N) dropout coin
    flips.  Returns (zeta', tau', aux) where aux maps ``unavail`` /
    ``dropout`` to 0/1 masks of contacts lost to that cause (counted
    first-cause-wins: an unavailable client's window never reaches the
    dropout coin).  Works elementwise on np or jnp operands — the
    differential test feeds both the SAME draws and asserts exact
    equality.
    """
    ok = zeta > 0
    tau_eff = tau - latency
    fits = tau_eff > 0
    lost_unavail = ok & ~avail
    lost_drop = ok & avail & fits & drop
    good = ok & avail & fits & ~drop
    zeta_out = good.astype(zeta.dtype if hasattr(zeta, "dtype") else int)
    tau_out = (tau_eff * good).astype(tau.dtype)
    aux = {
        "unavail": lost_unavail.astype(tau.dtype),
        "dropout": lost_drop.astype(tau.dtype),
    }
    return zeta_out, tau_out, aux


@dataclasses.dataclass(frozen=True)
class HeterogeneityModel:
    """Frozen (hashable) spec of the per-client heterogeneity process."""

    num_devices: int
    availability: float = 1.0  # stationary P(available); 1 disables
    avail_persist: float = 0.0  # state persistence rho in [0, 1)
    compute_mean: float = 0.0  # s, Exp mean compute latency; 0 disables
    dropout: float = 0.0  # P(upload lost despite a fitting window)
    seed: int = 0

    @classmethod
    def from_config(cls, fl, seed: Optional[int] = None):
        return cls(
            num_devices=fl.num_devices,
            availability=fl.het_availability,
            avail_persist=fl.het_avail_persist,
            compute_mean=fl.het_compute_mean,
            dropout=fl.het_dropout,
            seed=(fl.seed if seed is None else seed),
        )

    def enabled(self) -> bool:
        return (self.availability < 1.0 or self.compute_mean > 0.0
                or self.dropout > 0.0)

    # transition probabilities of the availability chain
    @property
    def p_stay_on(self) -> float:
        return self.avail_persist + (1 - self.avail_persist) * self.availability

    @property
    def p_recover(self) -> float:
        return (1 - self.avail_persist) * self.availability

    # -- NumPy oracle --------------------------------------------------------

    def sample_states(self, rounds: int, rng=None) -> np.ndarray:
        """(rounds, N) bool availability states (stationary start)."""
        rng = np.random.default_rng(self.seed) if rng is None else rng
        n = self.num_devices
        if self.availability >= 1.0:
            return np.ones((rounds, n), bool)
        avail = np.empty((rounds, n), bool)
        cur = rng.random(n) < self.availability  # stationary init
        for r in range(rounds):  # O(rounds) recurrence on (N,) vectors
            p = np.where(cur, self.p_stay_on, self.p_recover)
            cur = rng.random(n) < p
            avail[r] = cur
        return avail

    def draws(self, rounds: int, rng=None):
        """(avail, latency, drop) fixed draws for ``gate_windows``."""
        rng = np.random.default_rng(self.seed) if rng is None else rng
        n = self.num_devices
        avail = self.sample_states(rounds, rng)
        latency = (rng.exponential(self.compute_mean, (rounds, n))
                   if self.compute_mean > 0 else np.zeros((rounds, n)))
        drop = (rng.random((rounds, n)) < self.dropout
                if self.dropout > 0 else np.zeros((rounds, n), bool))
        return avail, latency.astype(np.float32), drop

    def apply(self, zeta, tau, rng=None):
        """Gate a NumPy (zeta, tau) schedule; returns (zeta', tau', aux)."""
        avail, latency, drop = self.draws(len(zeta), rng)
        return gate_windows(np.asarray(zeta), np.asarray(tau, np.float32),
                            avail, latency, drop)


# ---------------------------------------------------------------------------
# JAX twin (device-resident draws + gating, one jitted program)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("model", "rounds"))
def _jax_draws(model: HeterogeneityModel, key, rounds: int):
    n = model.num_devices
    ka, k0, kl, kd = jax.random.split(key, 4)
    if model.availability >= 1.0:
        avail = jnp.ones((rounds, n), bool)
    else:
        cur0 = jax.random.uniform(k0, (n,)) < model.availability

        def step(cur, k):
            p = jnp.where(cur, model.p_stay_on, model.p_recover)
            cur = jax.random.uniform(k, (n,)) < p
            return cur, cur

        _, avail = jax.lax.scan(step, cur0, jax.random.split(ka, rounds))
    latency = (model.compute_mean
               * jax.random.exponential(kl, (rounds, n), jnp.float32)
               if model.compute_mean > 0
               else jnp.zeros((rounds, n), jnp.float32))
    drop = (jax.random.uniform(kd, (rounds, n)) < model.dropout
            if model.dropout > 0 else jnp.zeros((rounds, n), bool))
    return avail, latency, drop


def jax_apply(model: HeterogeneityModel, zeta, tau, seed=None):
    """Gate a device-resident (zeta, tau) schedule without leaving the
    accelerator; returns (zeta', tau', aux) jnp arrays."""
    key = jax.random.key(model.seed if seed is None else seed)
    avail, latency, drop = _jax_draws(model, key, int(zeta.shape[0]))
    return gate_windows(jnp.asarray(zeta), jnp.asarray(tau, jnp.float32),
                        avail, latency, drop)


# ---------------------------------------------------------------------------
# Pure-Python reference simulator (tests only)
# ---------------------------------------------------------------------------


def reference_apply(zeta, tau, avail, latency, drop):
    """Per-(round, device) Python-loop restatement of ``gate_windows`` —
    the independent reference the heterogeneity unit tests compare the
    vectorized gating against (contact ∩ available, minus compute time,
    then the dropout coin)."""
    zeta = np.asarray(zeta)
    tau = np.asarray(tau, np.float32)
    rounds, n = zeta.shape
    z_out = np.zeros_like(zeta)
    t_out = np.zeros_like(tau)
    aux = {k: np.zeros((rounds, n), np.float32) for k in HET_COUNTER_KEYS}
    for r in range(rounds):
        for i in range(n):
            if not zeta[r, i]:
                continue
            if not avail[r, i]:
                aux["unavail"][r, i] = 1.0
                continue
            window = tau[r, i] - latency[r, i]
            if window <= 0:
                continue  # compute ate the whole contact window
            if drop[r, i]:
                aux["dropout"][r, i] = 1.0
                continue
            z_out[r, i] = 1
            t_out[r, i] = window
    return z_out, t_out, aux
