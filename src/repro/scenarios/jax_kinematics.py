"""Device-resident mobility kinematics: the JAX port of ``kinematics.py``.

The NumPy models in ``kinematics.py`` are the *oracle*: readable,
host-side, and statistically validated (tests/test_scenarios.py).  This
module re-implements the same four models as ``jit``/``vmap``-able JAX
programs so the whole scenario pipeline — trace -> in-range -> contact
intervals -> per-round (zeta, tau) -> position-coupled h2 — runs as ONE
compiled program on the accelerator, with zero host round-trips between
the PRNG draw and the finished (rounds, N) schedule.  That removes the
scenario wall between the compiled AFL engines (scan / pjit) and
million-device federations: generation cost scales with device FLOPs/
bandwidth, not with host Python (see benchmarks/bench_mobility.py).

Differences from the oracle, by construction:

* PRNG: ``jax.random`` (threefry) streams cannot reproduce
  ``np.random.default_rng`` draws, so JAX-vs-NumPy parity is *statistical*
  (distributional bounds + CI bands, tests/test_jax_scenarios.py).  The
  downstream contact extraction (``jax_contacts.py``) IS bit-comparable:
  on a shared in-range matrix it reproduces ``scenarios/contacts.py``
  intervals and ``mobility.contact.intervals_to_rounds`` cells exactly.
* Random waypoint draws a *static* leg budget (jit needs static shapes)
  instead of the oracle's redraw-until-covered loop.  The budget carries
  a 2.2x margin over the expected leg count plus 16 legs of slack; a
  device that exhausts it parks at its last waypoint (the same clamp
  ``np.interp`` applies past the final breakpoint).  At the oracle's
  1.8x + 8 budget a redraw is already rare; at 2.2x + 16 the parking
  probability is negligible for every tested horizon.
* Manhattan sizes its leg budget by the worst-case per-device speed
  (1.5 v) rather than the realised ``speeds.max()`` — a superset, never
  fewer legs than the oracle would allocate.

Every model is a frozen (hashable) dataclass satisfying the same
``MobilityModel`` protocol (``num_devices`` / ``area`` / ``mean_speed`` /
``trace``), so ``ScenarioProvider`` treats both backends uniformly.
Memory note: a trace materialises (steps, N, 2) f32 positions on device
(~0.8 GB at N=1e5, steps=1000); for N -> 1e6 keep the horizon short or
generate round-blocks per call — the models are pure functions of
(key, steps), so block-wise generation composes.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "JaxTrace",
    "JaxRandomWaypointModel",
    "JaxGaussMarkovModel",
    "JaxManhattanGridModel",
    "JaxHotspotClusterModel",
    "JAX_MODELS",
    "jax_gains_along_trace",
    "jax_schedule_from_model",
]


@dataclasses.dataclass
class JaxTrace:
    """Device-resident twin of ``kinematics.Trace`` (jnp arrays)."""

    pos: jax.Array  # (steps, num_devices, 2) f32, metres
    mes: jax.Array  # (steps, 2) MES position
    dt: float

    @property
    def steps(self) -> int:
        return self.pos.shape[0]

    @property
    def num_devices(self) -> int:
        return self.pos.shape[1]

    def distances(self) -> jax.Array:
        return jnp.linalg.norm(self.pos - self.mes[:, None, :], axis=-1)

    def in_range(self, comm_range: float) -> jax.Array:
        return self.distances() < comm_range

    def to_numpy(self):
        """Host materialisation as the oracle's ``Trace`` (tests only)."""
        from repro.scenarios.kinematics import Trace

        return Trace(pos=np.asarray(self.pos), mes=np.asarray(self.mes),
                     dt=self.dt)


def _reflect(x, hi: float):
    """Fold unbounded coordinates into [0, hi] by reflection at the walls."""
    y = jnp.mod(x, 2.0 * hi)
    return jnp.where(y > hi, 2.0 * hi - y, y)


def _static_mes(steps: int, area: float):
    return jnp.full((steps, 2), 0.5 * area, jnp.float32)


# ---------------------------------------------------------------------------
# Position kernels (pure, jittable; model dataclasses are static args)
# ---------------------------------------------------------------------------


def _rwp_positions(key, steps: int, dt: float, n: int, area: float,
                   mean_speed: float, pause_max: float):
    """Leg-based random waypoint, fully batched.

    The oracle's per-entity ``np.interp`` loop becomes one vmapped
    ``searchsorted`` + gather over the (n, 2m) breakpoint table — the
    O(N) Python loop that dominates NumPy generation at N >= 1e4
    disappears entirely.
    """
    duration = steps * dt
    est_leg = 0.5214 * area / max(mean_speed, 1e-9) + 0.5 * pause_max
    m = int(duration / max(est_leg, 1e-9) * 2.2) + 16  # static budget
    kn, ks, kp = jax.random.split(key, 3)
    nodes = jax.random.uniform(kn, (n, m + 1, 2), jnp.float32, 0.0, area)
    speeds = jax.random.uniform(ks, (n, m), jnp.float32,
                                0.5 * mean_speed, 1.5 * mean_speed)
    pauses = jax.random.uniform(kp, (n, m), jnp.float32, 0.0, pause_max)
    travel = (jnp.linalg.norm(jnp.diff(nodes, axis=1), axis=-1)
              / jnp.maximum(speeds, 1e-9))
    leg_start = jnp.cumsum(travel + pauses, axis=1) - (travel + pauses)

    # breakpoints: (depart, node_k) then (arrive, node_{k+1}) per leg —
    # renders motion and pause (flat segment) exactly like the oracle
    tp = jnp.stack([leg_start, leg_start + travel], axis=2).reshape(n, 2 * m)
    xs = jnp.stack([nodes[:, :-1], nodes[:, 1:]], axis=2).reshape(n, 2 * m, 2)

    tq = jnp.arange(steps, dtype=jnp.float32) * dt
    # bucketed lookup on the uniform query grid: a breakpoint at time t is
    # <= tq[j] exactly for j >= ceil(t/dt), so per-row bucket counts of
    # ceil(tp/dt) followed by a cumsum reproduce
    # searchsorted(tp, tq, side="right") in O(m + steps) work per device
    # instead of the vmapped O(steps log m) binary search (which left
    # jitted RWP barely ahead of the NumPy oracle).  An off-by-one at a
    # breakpoint sitting within one ulp of a grid point is positionally
    # harmless: adjacent segments share the breakpoint node, so both leg
    # choices interpolate to the same position
    q0 = jnp.clip(jnp.ceil(tp / dt).astype(jnp.int32), 0, steps)
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    # int16 carries the running count (<= 2m « 32767) at half the cumsum
    # memory traffic — the scan is bandwidth-bound on CPU
    cnt = jnp.zeros((n, steps + 1), jnp.int16).at[rows, q0].add(
        jnp.int16(1))
    idx = jnp.cumsum(cnt[:, :steps], axis=1).astype(jnp.int32)
    i1 = jnp.clip(idx, 1, 2 * m - 1)
    i0 = i1 - 1
    t0 = jnp.take_along_axis(tp, i0, axis=1)  # (n, steps)
    t1 = jnp.take_along_axis(tp, i1, axis=1)
    x0 = jnp.take_along_axis(xs, i0[:, :, None], axis=1)  # (n, steps, 2)
    x1 = jnp.take_along_axis(xs, i1[:, :, None], axis=1)
    den = t1 - t0
    frac = jnp.clip(jnp.where(den > 0, (tq[None] - t0)
                              / jnp.maximum(den, 1e-12), 1.0), 0.0, 1.0)
    pos = x0 + frac[:, :, None] * (x1 - x0)
    return pos.transpose(1, 0, 2)  # (steps, n, 2)


def _gm_positions(key, steps: int, dt: float, n: int, area: float,
                  mean_speed: float, corr_dist: float):
    """AR(1) velocity with reflecting walls — ``lax.scan`` over steps on an
    (n, 2) carry, identical recurrence to the oracle."""
    alpha = float(np.exp(-dt * mean_speed / max(corr_dist, 1e-9)))
    sig_c = mean_speed / float(np.sqrt(np.pi / 2.0))
    scale = sig_c * float(np.sqrt(max(1.0 - alpha * alpha, 0.0)))
    kn, kv, kx = jax.random.split(key, 3)
    noise = jax.random.normal(kn, (steps, n, 2), jnp.float32)
    v0 = sig_c * jax.random.normal(kv, (n, 2), jnp.float32)
    x0 = jax.random.uniform(kx, (n, 2), jnp.float32, 0.0, area)

    # integrate displacement inside the scan carry: a separate
    # ``jnp.cumsum`` over the (steps, n, 2) velocity array is the single
    # most expensive op in the pipeline on CPU (XLA lowers it to log-depth
    # passes over the full array), while extending the carry is ~free
    def step(carry, eps):
        v, s = carry
        v = alpha * v + scale * eps
        s = s + v * dt
        return (v, s), s

    _, disp = jax.lax.scan(step, (v0, jnp.zeros_like(v0)), noise)
    return _reflect(x0[None] + disp, area)


_DIRS = jnp.asarray([[1.0, 0.0], [0.0, 1.0], [-1.0, 0.0], [0.0, -1.0]],
                    jnp.float32)


def _manhattan_positions(key, steps: int, dt: float, n: int, area: float,
                         mean_speed: float, block: float, p_turn: float):
    """Lattice streets, i.i.d. turns — the oracle is already closed-form
    (cumsum of turns + direct leg-index divide) and ports one-to-one."""
    grid_n = max(int(round(area / block)), 1)
    a = grid_n * block
    duration = steps * dt
    m = int(duration * 1.5 * mean_speed / block) + 2  # worst-case speed

    ks, kt, kh, kx = jax.random.split(key, 4)
    speeds = jnp.maximum(
        jax.random.uniform(ks, (n,), jnp.float32,
                           0.5 * mean_speed, 1.5 * mean_speed), 1e-9)
    u = jax.random.uniform(kt, (n, m), jnp.float32)
    turn = jnp.where(u < 0.5 * p_turn, 1, jnp.where(u < p_turn, -1, 0))
    head0 = jax.random.randint(kh, (n,), 0, 4)
    head = (head0[:, None] + jnp.concatenate(
        [jnp.zeros((n, 1), jnp.int32), jnp.cumsum(turn, axis=1)[:, :-1]],
        axis=1)) % 4
    start = (jax.random.randint(kx, (n, 2), 0, grid_n + 1)
             .astype(jnp.float32) * block)
    nodes = start[:, None, :] + block * jnp.concatenate(
        [jnp.zeros((n, 1, 2), jnp.float32), jnp.cumsum(_DIRS[head], axis=1)],
        axis=1)
    # reflection folds lattice points onto lattice points (block | area)
    nodes = _reflect(nodes, a)

    leg_dur = block / speeds  # (n,)
    tq = jnp.arange(steps, dtype=jnp.float32) * dt
    pos_t = tq[None, :] / leg_dur[:, None]
    idx = jnp.clip(pos_t.astype(jnp.int32), 0, m - 1)
    frac = jnp.clip(pos_t - idx, 0.0, 1.0)
    gather = jnp.broadcast_to(idx[:, :, None], (n, steps, 2))
    p0 = jnp.take_along_axis(nodes, gather, axis=1)
    p1 = jnp.take_along_axis(nodes, gather + 1, axis=1)
    pos = p0 + frac[:, :, None] * (p1 - p0)
    return pos.transpose(1, 0, 2), a


def _hotspot_positions(key, steps: int, dt: float, n: int, area: float,
                       mean_speed: float, num_hotspots: int, radius: float):
    """OU excursion around hotspot anchors; ``mean_speed == 0`` devolves to
    the static crowd (a compile-time branch — the model is a static arg)."""
    kc, ka, ko, kv, kn = jax.random.split(key, 5)
    centers = jax.random.uniform(kc, (num_hotspots, 2), jnp.float32,
                                 0.15 * area, 0.85 * area)
    anchor = centers[jax.random.randint(ka, (n,), 0, num_hotspots)]
    sig_c = radius / float(np.sqrt(2.0))
    off0 = sig_c * jax.random.normal(ko, (n, 2), jnp.float32)
    if mean_speed <= 0:  # static scenario
        pos = jnp.clip(anchor + off0, 0.0, area)
        return jnp.broadcast_to(pos[None], (steps, n, 2))

    rate = mean_speed / max(radius, 1e-9)
    alpha = float(np.exp(-dt * rate))
    vel_sig = mean_speed / float(np.sqrt(np.pi / 2.0))
    scale = vel_sig * float(np.sqrt(max(1.0 - alpha * alpha, 0.0)))
    vel0 = vel_sig * jax.random.normal(kv, (n, 2), jnp.float32)
    noise = jax.random.normal(kn, (steps, n, 2), jnp.float32)

    def step(carry, eps):
        off, vel = carry
        vel = alpha * vel - (1.0 - alpha) * rate * off + scale * eps
        off = off + vel * dt
        return (off, vel), off

    _, offs = jax.lax.scan(step, (off0, vel0), noise)
    return jnp.clip(anchor[None] + offs, 0.0, area)


# ---------------------------------------------------------------------------
# Models (frozen -> hashable -> usable as jit static args)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("model", "steps", "dt"))
def _model_positions(model, key, steps: int, dt: float):
    """One jit entry for every model: ``(pos, mes)`` device arrays."""
    return model._positions(key, steps, dt)


class _JaxModelBase:
    """Shared ``trace``/key plumbing for the four models below."""

    def key(self) -> jax.Array:
        return jax.random.key(self.seed)

    def trace(self, duration: float, dt: float = 1.0) -> JaxTrace:
        steps = int(duration / dt)
        pos, mes = _model_positions(self, self.key(), steps, float(dt))
        return JaxTrace(pos=pos, mes=mes, dt=float(dt))


@dataclasses.dataclass(frozen=True)
class JaxRandomWaypointModel(_JaxModelBase):
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0  # m/s; per-leg speeds ~ U(0.5v, 1.5v)
    pause_max: float = 5.0
    seed: int = 0

    def _positions(self, key, steps: int, dt: float):
        pos = _rwp_positions(key, steps, dt, self.num_devices, self.area,
                             self.mean_speed, self.pause_max)
        return pos, _static_mes(steps, self.area)


@dataclasses.dataclass(frozen=True)
class JaxGaussMarkovModel(_JaxModelBase):
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0
    corr_dist: float = 200.0  # inverse-speed law by construction (oracle)
    seed: int = 0

    def _positions(self, key, steps: int, dt: float):
        pos = _gm_positions(key, steps, dt, self.num_devices, self.area,
                            self.mean_speed, self.corr_dist)
        return pos, _static_mes(steps, self.area)


@dataclasses.dataclass(frozen=True)
class JaxManhattanGridModel(_JaxModelBase):
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0
    block: float = 100.0
    p_turn: float = 0.5
    seed: int = 0

    def _positions(self, key, steps: int, dt: float):
        pos, a = _manhattan_positions(
            key, steps, dt, self.num_devices, self.area, self.mean_speed,
            self.block, self.p_turn)
        return pos, _static_mes(steps, a)


@dataclasses.dataclass(frozen=True)
class JaxHotspotClusterModel(_JaxModelBase):
    num_devices: int = 20
    area: float = 1000.0
    mean_speed: float = 10.0  # 0 -> perfectly static devices
    num_hotspots: int = 4
    hotspot_radius: float = 150.0
    seed: int = 0

    def _positions(self, key, steps: int, dt: float):
        pos = _hotspot_positions(
            key, steps, dt, self.num_devices, self.area, self.mean_speed,
            self.num_hotspots, self.hotspot_radius)
        return pos, _static_mes(steps, self.area)


JAX_MODELS = {
    "rwp": JaxRandomWaypointModel,
    "gauss_markov": JaxGaussMarkovModel,
    "manhattan": JaxManhattanGridModel,
    "hotspot": JaxHotspotClusterModel,
}


# ---------------------------------------------------------------------------
# Position-coupled channel gains (JAX port of scenarios/channel.py)
# ---------------------------------------------------------------------------


def jax_gains_along_trace(key, pos, mes, *, carrier_ghz: float = 3.5,
                          shadow_los_db: float = 4.0,
                          shadow_nlos_db: float = 8.2,
                          shadow_corr_dist: float = 25.0):
    """|h|^2 per (round, device) from per-round positions, on device.

    Same TR 38.901 UMi model as ``gains_along_trace``: distance path loss,
    Gudmundson AR(1) lognormal shadowing (round-to-round correlation
    ``exp(-displacement / shadow_corr_dist)``), and a persistent LOS state
    redrawn only when the device moves.  The O(rounds) host recurrence
    becomes a ``lax.scan`` carrying the (n,) LOS/shadowing state.
    Innovations come from ``jax.random``, so gains match the NumPy path in
    distribution, not bitwise.
    """
    d = jnp.linalg.norm(pos - mes[:, None, :], axis=-1)  # (R, n)
    r_total, n = d.shape
    dm = jnp.maximum(d, 1e-9)
    p_los = jnp.where(d <= 18.0, 1.0,
                      jnp.minimum(18.0 / dm + jnp.exp(-d / 36.0)
                                  * (1.0 - 18.0 / dm), 1.0))
    disp = jnp.concatenate(
        [jnp.zeros((1, n)), jnp.linalg.norm(pos[1:] - pos[:-1], axis=-1)]
    )
    rho = jnp.exp(-disp / max(shadow_corr_dist, 1e-9))
    # round 0 draws fresh LOS/shadowing state: zero correlation with the
    # (all-zeros) initial carry
    rho = rho.at[0].set(0.0)

    keys = jax.random.split(key, r_total)

    def step(carry, xs):
        los_p, z_p = carry
        k, rho_r, p_r = xs
        k1, k2, k3 = jax.random.split(k, 3)
        redraw = jax.random.uniform(k1, (n,)) >= rho_r
        los = jnp.where(redraw, jax.random.uniform(k2, (n,)) < p_r, los_p)
        z = rho_r * z_p + jnp.sqrt(jnp.maximum(1.0 - rho_r**2, 0.0)) \
            * jax.random.normal(k3, (n,))
        return (los, z), (los, z)

    init = (jnp.zeros((n,), bool), jnp.zeros((n,)))
    _, (los, z) = jax.lax.scan(step, init, (keys, rho, p_los))

    dcl = jnp.maximum(d, 1.0)
    pl = (32.4 + jnp.where(los, 21.0, 31.9) * jnp.log10(dcl)
          + 20.0 * float(np.log10(carrier_ghz)))
    sigma = jnp.where(los, shadow_los_db, shadow_nlos_db)
    return (10.0 ** (-(pl + sigma * z) / 10.0)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# End-to-end jitted schedule: trace -> contacts -> (zeta, tau, h2)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("model", "rounds", "round_duration",
                                   "dt", "comm_range", "shadow_corr_dist",
                                   "carrier_ghz", "drop_truncated"))
def _schedule(model, key, rounds: int, round_duration: float, dt: float,
              comm_range: float, shadow_corr_dist: float,
              carrier_ghz: float, drop_truncated: bool):
    from repro.scenarios.jax_contacts import rounds_from_in_range

    steps = int(rounds * round_duration / dt)
    kt, kc = jax.random.split(key)
    pos, mes = model._positions(kt, steps, dt)
    dist = jnp.linalg.norm(pos - mes[:, None, :], axis=-1)
    zeta, tau = rounds_from_in_range(
        dist < comm_range, dt, rounds, round_duration,
        drop_truncated=drop_truncated)
    # per-round sample index (same non-drifting derivation as the oracle)
    ridx = np.minimum(
        (np.arange(rounds) * (round_duration / dt)).astype(np.int64),
        steps - 1,
    )
    h2 = jax_gains_along_trace(
        kc, pos[ridx], mes[ridx], carrier_ghz=carrier_ghz,
        shadow_corr_dist=shadow_corr_dist)
    return zeta, tau, h2


def jax_schedule_from_model(model, rounds: int, round_duration: float,
                            *, dt: float = 1.0, comm_range: float = 100.0,
                            shadow_corr_dist: float = 25.0,
                            carrier_ghz: float = 3.5,
                            drop_truncated: bool = False, seed=None):
    """(zeta, tau, h2) device arrays from a JAX mobility model, one compile.

    The entire pipeline — PRNG draws, kinematics, in-range test, interval
    extraction, round mapping, channel gains — is a single jitted program:
    no intermediate ever crosses to the host (the acceptance criterion's
    "zero mid-trace host syncs").  ``drop_truncated`` drops contacts still
    open at the trace end instead of censoring them at the window (the
    ``measure_contact_stats`` truncation fix, mirrored on device).
    """
    key = model.key() if seed is None else jax.random.key(seed)
    return _schedule(model, key, int(rounds), float(round_duration),
                     float(dt), float(comm_range), float(shadow_corr_dist),
                     float(carrier_ghz), bool(drop_truncated))
