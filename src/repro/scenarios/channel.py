"""Position-coupled channel gains for scenario traces.

Replaces the seed's i.i.d. ``WirelessChannel.sample_gain`` shortcut: |h|^2
is derived from the *actual* device-MES distance each round through the
existing TR 38.901 path-loss model, with

* lognormal shadowing evolved as a Gudmundson spatially-correlated AR(1)
  process — the correlation between consecutive rounds is
  exp(-displacement / shadow_corr_dist), so slow devices see correlated
  good/bad channels across a contact while vehicular traces decorrelate;
* a persistent LOS/NLOS state redrawn (from the distance-dependent UMi LOS
  probability) only with probability 1 - exp(-displacement / corr_dist),
  i.e. the blockage environment changes when the device actually moves.
"""
from __future__ import annotations

import numpy as np

from repro.channel.wireless import WirelessChannel


def gains_along_trace(channel: WirelessChannel, pos: np.ndarray,
                      mes: np.ndarray, shadow_corr_dist: float = 25.0,
                      rng=None, seed: int = 0) -> np.ndarray:
    """|h|^2 per (round, device) from per-round positions.

    pos: (rounds, num_devices, 2); mes: (rounds, 2).  Returns (rounds, N).
    """
    rng = np.random.default_rng(seed) if rng is None else rng
    d = np.linalg.norm(pos - mes[:, None, :], axis=-1)  # (R, n)
    r_total, n = d.shape
    p_los = channel.los_prob(d)

    disp = np.zeros((r_total, n))
    disp[1:] = np.linalg.norm(pos[1:] - pos[:-1], axis=-1)
    rho = np.exp(-disp / max(shadow_corr_dist, 1e-9))

    los = np.empty((r_total, n), bool)
    z = np.empty((r_total, n))  # unit-variance shadowing innovations state
    los[0] = rng.random(n) < p_los[0]
    z[0] = rng.normal(0.0, 1.0, n)
    for r in range(1, r_total):  # O(rounds) recurrence on (n,) vectors
        redraw = rng.random(n) >= rho[r]
        los[r] = np.where(redraw, rng.random(n) < p_los[r], los[r - 1])
        z[r] = rho[r] * z[r - 1] + np.sqrt(1.0 - rho[r] ** 2) * rng.normal(
            0.0, 1.0, n
        )

    sigma = np.where(los, channel.shadow_los_db, channel.shadow_nlos_db)
    pl = channel.pathloss_db(d, los)
    return 10.0 ** (-(pl + sigma * z) / 10.0)
