from repro.scenarios.channel import gains_along_trace
from repro.scenarios.contacts import contact_intervals, rounds_from_trace
from repro.scenarios.heterogeneity import HeterogeneityModel, gate_windows
from repro.scenarios.jax_contacts import (
    contact_intervals_jax,
    rounds_from_in_range,
)
from repro.scenarios.jax_kinematics import (
    JAX_MODELS,
    JaxGaussMarkovModel,
    JaxHotspotClusterModel,
    JaxManhattanGridModel,
    JaxRandomWaypointModel,
    JaxTrace,
    jax_gains_along_trace,
    jax_schedule_from_model,
)
from repro.scenarios.kinematics import (
    GaussMarkovModel,
    HotspotClusterModel,
    ManhattanGridModel,
    MobilityModel,
    RandomWaypointModel,
    Trace,
)
from repro.scenarios.provider import (
    MODELS,
    ScenarioProvider,
    jax_model_from_config,
    model_from_config,
)

__all__ = [
    "GaussMarkovModel",
    "HotspotClusterModel",
    "ManhattanGridModel",
    "MobilityModel",
    "RandomWaypointModel",
    "Trace",
    "JAX_MODELS",
    "JaxGaussMarkovModel",
    "JaxHotspotClusterModel",
    "JaxManhattanGridModel",
    "JaxRandomWaypointModel",
    "JaxTrace",
    "HeterogeneityModel",
    "MODELS",
    "ScenarioProvider",
    "model_from_config",
    "jax_model_from_config",
    "contact_intervals",
    "contact_intervals_jax",
    "rounds_from_trace",
    "rounds_from_in_range",
    "gains_along_trace",
    "jax_gains_along_trace",
    "jax_schedule_from_model",
    "gate_windows",
]
