from repro.scenarios.channel import gains_along_trace
from repro.scenarios.contacts import contact_intervals, rounds_from_trace
from repro.scenarios.kinematics import (
    GaussMarkovModel,
    HotspotClusterModel,
    ManhattanGridModel,
    MobilityModel,
    RandomWaypointModel,
    Trace,
)
from repro.scenarios.provider import MODELS, ScenarioProvider, model_from_config

__all__ = [
    "GaussMarkovModel",
    "HotspotClusterModel",
    "ManhattanGridModel",
    "MobilityModel",
    "RandomWaypointModel",
    "Trace",
    "MODELS",
    "ScenarioProvider",
    "model_from_config",
    "contact_intervals",
    "rounds_from_trace",
    "gains_along_trace",
]
