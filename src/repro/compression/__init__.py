"""Gradient compression under a contact-time bit budget (see base.py).

One contract — ``compress(x, budget_bits, state) -> (payload, state,
stats)`` — four codecs: top-k (Proposition 1), QSGD-style dense
quantisation, the closed-form joint (k, b) codec (optionally with
per-layer (k_l, b_l) budgets, see perlayer.py), and a budget-clipped
fixed-(k, b) baseline.  ``core.afl.Policy.compressor`` wires any of them
into the single-host engines AND the pjit distributed step
(``core/distributed.py``); ``core/README.md`` maps the math and the
sharded-threshold contract.
"""
from repro.compression.base import Compressor, CompressorState, init_state
from repro.compression.joint import JointCompressor, solve_kb
from repro.compression.perlayer import (
    solve_kb_per_leaf,
    split_score,
    uniform_split,
)
from repro.compression.qsgd import QSGDCompressor
from repro.compression.quant import (
    SCALE_BITS,
    dither_u01,
    quant_levels,
    quant_step,
    stochastic_round,
    tree_amax,
)
from repro.compression.topk import FixedKbCompressor, TopKCompressor

__all__ = [
    "Compressor",
    "CompressorState",
    "FixedKbCompressor",
    "JointCompressor",
    "QSGDCompressor",
    "SCALE_BITS",
    "TopKCompressor",
    "dither_u01",
    "init_state",
    "quant_levels",
    "quant_step",
    "solve_kb",
    "solve_kb_per_leaf",
    "split_score",
    "stochastic_round",
    "tree_amax",
    "uniform_split",
]
