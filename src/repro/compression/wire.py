"""Wire format for compressed client uploads (the serve-path payloads).

Every codec in this package produces a *dense dequantised* payload tree —
the right interface for the simulation engines, where the MES aggregation
is a tensor contraction on device.  A streaming aggregation server
(``repro/serve``) instead receives uploads one at a time over the network,
so this module defines the (de)serialisation contract between them:

* ``WirePayload`` — one upload on the wire: sorted flat coordinates, the
  value codes, a quantisation step, and the header scalars the server
  needs for staleness-weighted mixing (device id, the model-version round
  ``rnd`` the upload was computed against, the billed ``bits``).
* Value codes are ``int32`` carrying either the *b-bit integer grid codes*
  (``b < 32``: the stochastic-rounding output ``q`` of
  ``compression.quant``, dequantised server-side as ``q * step`` — the
  exact float multiply the codecs perform, so decode is bit-identical to
  the dense payload) or the *raw float32 bit pattern* (``b == 32``,
  bitcast, ``step == 1``).
* ``pack_batch`` pads a list of payloads onto static ``(batch, max_k)``
  device arrays (pad coordinate = ``s``, dropped by the scatter), which
  is what makes the server's decompress+aggregate ONE jitted program over
  the whole batch instead of a per-upload loop.

Bit accounting mirrors ``base.Compressor``: ``k * (b + ceil(log2 s))``
index+value bits plus one 32-bit scale per quantised message (eq. 7c).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import quant as Q

__all__ = ["WirePayload", "encode_upload", "pack_batch", "decode_values",
           "index_bits"]


def index_bits(s: int) -> int:
    """Per-coordinate position overhead on the wire (paper eq. 7c)."""
    return int(math.ceil(math.log2(max(s, 2))))


class WirePayload(NamedTuple):
    """One compressed upload as serialised for the aggregation server."""

    coords: np.ndarray  # (k,) int32 flat coordinate indices, ascending
    codes: np.ndarray  # (k,) int32 grid codes (b<32) or f32 bit patterns
    step: float  # quantisation step (1.0 for raw float values)
    b: float  # value bit-width on the wire (32 = raw float32)
    k: int  # number of shipped coordinates
    device: int = 0  # uploading client id
    rnd: int = 0  # model-version round the upload was computed against
    ok: float = 1.0  # client-side feasibility mask (0 withholds mixing)
    bits: float = 0.0  # billed wire bits (header; k (b + log2 s) + scale)


def encode_upload(payload_tree, *, b: float = 32.0, step: float = 1.0,
                  device: int = 0, rnd: int = 0, ok: float = 1.0,
                  max_k: int | None = None) -> WirePayload:
    """Serialise one dense dequantised payload tree onto the wire.

    ``b``/``step`` come from the codec's per-upload stats (``stats["b"]``
    and the message's quantisation step); ``b >= 32`` (or a zero/absent
    step) ships raw float32 bit patterns instead of grid codes.  Host-side
    by design — encoding happens at the *client*, the server only ever
    decodes.  Raises if the upload carries more than ``max_k`` nonzeros
    (an oversized payload must be rejected at the edge, not truncated
    silently).
    """
    leaves = jax.tree.leaves(payload_tree)
    flat = np.concatenate(
        [np.asarray(l, np.float32).reshape(-1) for l in leaves])
    s = flat.size
    nz = np.flatnonzero(flat)
    if max_k is not None and nz.size > max_k:
        raise ValueError(
            f"upload has {nz.size} nonzero coords > max_k={max_k}")
    vals = flat[nz]
    b = float(b)
    quantized = b < 32.0 and step > 0.0
    if quantized:
        # recover the integer grid codes: vals = q * step with |q| small,
        # so the float64 divide rounds back to q exactly
        codes = np.rint(vals.astype(np.float64) / step).astype(np.int32)
    else:
        codes = vals.view(np.int32)
        step, b = 1.0, 32.0
    k = int(nz.size)
    bits = k * (b + index_bits(s)) + (Q.SCALE_BITS if (quantized and k) else 0)
    return WirePayload(coords=nz.astype(np.int32), codes=codes,
                       step=float(step), b=b, k=k, device=int(device),
                       rnd=int(rnd), ok=float(ok), bits=float(bits))


def pack_batch(payloads: Sequence[WirePayload], *, s: int, max_k: int,
               batch: int, server_round: int = 0) -> dict:
    """Pad up to ``batch`` payloads onto static-shape arrays for the
    fused ingest op.

    Pad coordinate is ``s`` (out of range — the scatter drops it); empty
    slots carry ``mask = 0`` and contribute exact zeros to the weighted
    contraction.  ``dtau`` is the server-side staleness
    ``server_round - payload.rnd`` (clipped at 0) that the
    ``alpha * s(delta_tau)`` mixing family consumes.
    """
    if len(payloads) > batch:
        raise ValueError(f"{len(payloads)} payloads > batch={batch}")
    coords = np.full((batch, max_k), s, np.int32)
    codes = np.zeros((batch, max_k), np.int32)
    steps = np.ones((batch,), np.float32)
    bw = np.full((batch,), 32.0, np.float32)
    dtau = np.zeros((batch,), np.float32)
    mask = np.zeros((batch,), np.float32)
    bits = np.zeros((batch,), np.float32)
    for i, p in enumerate(payloads):
        if p.k > max_k:
            raise ValueError(f"payload k={p.k} > max_k={max_k}")
        coords[i, : p.k] = p.coords
        codes[i, : p.k] = p.codes
        steps[i] = p.step
        bw[i] = p.b
        dtau[i] = max(server_round - p.rnd, 0)
        mask[i] = p.ok
        bits[i] = p.bits
    return {"coords": coords, "codes": codes, "step": steps, "b": bw,
            "dtau": dtau, "mask": mask, "bits": bits}


def decode_values(codes, steps, bwidths):
    """Dequantise a packed ``(B, K)`` code block (jnp, jit-traceable).

    ``b < 32`` rows decode as ``codes * step`` — the same single float32
    multiply the codecs' ``stochastic_round`` performed, hence bit-equal
    to the dense payload — and ``b == 32`` rows bitcast the raw float
    pattern back.
    """
    codes = jnp.asarray(codes, jnp.int32)
    grid = codes.astype(jnp.float32) * jnp.asarray(steps, jnp.float32)[:, None]
    raw = jax.lax.bitcast_convert_type(codes, jnp.float32)
    return jnp.where(jnp.asarray(bwidths, jnp.float32)[:, None] < 32.0,
                     grid, raw)
