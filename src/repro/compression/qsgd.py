"""QSGD-style quantise-everything codec: bit-width traced from the budget.

No sparsification and therefore no per-coordinate index overhead: all ``s``
coordinates ship at ``b = floor((budget - 32) / s)`` bits each (the 32 pays
the fp32 scale), stochastically rounded onto the ``2^(b-1)-1``-level grid
(``compression.quant``).  When the contact window cannot afford ``b_min``
bits per coordinate the device sends nothing — dense quantisation degrades
ungracefully under short contacts, which is exactly the regime where the
joint (k, b) codec wins (see ``joint.py``).

``b`` is a *traced* value: the same compiled program serves every contact
duration, with the bit-width resolved per device per round inside the jitted
AFL round — no recompilation across budgets.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.compression import quant as Q
from repro.compression.base import Compressor, CompressorState


@dataclasses.dataclass(frozen=True)
class QSGDCompressor(Compressor):
    b_min: int = 2
    b_max: int = 16

    def compress(self, x, budget_bits, state: CompressorState):
        xt = self.combined(x, state)
        b = jnp.floor((budget_bits - Q.SCALE_BITS) / self.s)
        b = jnp.clip(b, 0.0, float(self.b_max))
        send = (b >= self.b_min).astype(jnp.float32)
        b = b * send
        levels = Q.quant_levels(b)
        step = Q.quant_step(Q.tree_amax(xt, axis=self.axis), levels)
        # threshold 0 selects every coordinate; send=0 withholds the round
        payload, error, _ = self.masked_payload(
            xt, jnp.float32(0.0), quantize=True, step=step, levels=levels,
            seed=self.dither_seed(state),
        )
        payload = jax.tree.map(lambda p: (p * send).astype(p.dtype), payload)
        error = jax.tree.map(
            lambda e, x_: jnp.where(send > 0, e, x_), error, xt)
        # bits <= budget by construction: b = floor((budget - 32) / s)
        bits = send * (float(self.s) * b + Q.SCALE_BITS)
        stats = {"k": send * float(self.s), "bits": bits, "b": b,
                 "step": jnp.asarray(step, jnp.float32)}
        return payload, self.next_state(error, state), stats
