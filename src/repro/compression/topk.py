"""Top-k codecs: Proposition 1 spending, plus the fixed-(k, b) baseline.

``TopKCompressor`` is MADS's original spend generalised to a configurable
value width ``u``: the budget buys ``k = floor(budget / (u + log2 s))``
coordinates, selected by a global tie-immune magnitude threshold
(``base.strict_threshold``) and transmitted as ``u``-bit values
(raw floats at u=32, stochastically quantised below).  ``FixedKbCompressor``
ignores the budget for its *targets* — a fixed keep-fraction and bit-width
— but clips k to what the contact window can actually carry, so realised
bits never exceed the budget (the honest version of a fixed-rate baseline
under mobility).  Both delegate thresholding, bit accounting, and the
budget gate to ``base.Compressor.spend``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.compression import quant as Q
from repro.compression.base import Compressor, CompressorState


@dataclasses.dataclass(frozen=True)
class TopKCompressor(Compressor):
    """Sparsify-only spend: ``k = floor(budget / (u + log2 s))``."""

    u: int = 32  # value bit-width on the wire

    def compress(self, x, budget_bits, state: CompressorState):
        xt = self.combined(x, state)
        quantize = self.u < 32
        overhead = Q.SCALE_BITS if quantize else 0
        k_target = jnp.floor(jnp.clip(
            (budget_bits - overhead) / (self.u + self.index_bits),
            0.0, float(self.s),
        ))
        return self.spend(xt, k_target, self.u, budget_bits, state,
                          quantize=quantize)


@dataclasses.dataclass(frozen=True)
class FixedKbCompressor(Compressor):
    """Fixed (keep-fraction, bit-width) targets, clipped to the budget.

    The classic static-rate baseline: it neither adapts k to the contact
    window (wasting capacity on long contacts) nor b to the budget
    (starving k on short ones) — the ablation the joint codec beats.
    """

    k_frac: float = 0.01
    b: int = 8

    def compress(self, x, budget_bits, state: CompressorState):
        xt = self.combined(x, state)
        quantize = self.b < 32
        overhead = Q.SCALE_BITS if quantize else 0
        k_cap = jnp.floor(jnp.clip(
            (budget_bits - overhead) / (self.b + self.index_bits),
            0.0, float(self.s),
        ))
        k_target = jnp.minimum(jnp.floor(self.k_frac * self.s), k_cap)
        return self.spend(xt, k_target, self.b, budget_bits, state,
                          quantize=quantize)
