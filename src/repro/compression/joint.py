"""Joint sparsify-then-quantize codec: the (k, b) split in closed form.

The MADS budget (Proposition 1) is ``B = tau * A(p)`` bits.  Spending it on
``k`` coordinates at ``b``-bit values costs

    B  >=  k * (b + lambda) + 32,        lambda = ceil(log2 s),

(the 32 is the fp32 scale) so the keep-fraction at bit-width ``b`` is

    kappa(b) = min(1, (B - 32) / (s * (b + lambda))).

**Distortion model.**  Top-k keeps at least a ``kappa`` fraction of the
signal energy (the random-k lower bound; magnitude selection only does
better), and ``b``-bit stochastic rounding onto the ``2^(b-1)-1``-level
grid leaves a noise fraction

    eps(b) = 4^{-(b-1)} / 3

of the kept energy (uniform-value estimate: step ``delta = amax/levels``,
per-coordinate MSE ``delta^2/12`` against mean-square value ``amax^2/3``).
Relative end-to-end distortion is then

    D(b) = 1 - kappa(b) * (1 - eps(b)),

so the optimal width maximises the "useful energy per bit" score

    b* = argmax_b  kappa(b) * (1 - eps(b)).

The two limits behave correctly: as ``b -> infinity`` kappa shrinks like
``1/b`` (all budget burnt on precision), as ``b -> b_min`` eps blows up
(all budget on coordinates nobody can decode accurately); the maximiser
sits at a few bits — and because ``kappa`` saturates at 1 for large
budgets, ``b*`` automatically grows toward ``b_max`` when the window is
long enough to ship everything.

**Closed form.**  D(b) is evaluated on the static integer grid ``b_grid``
in one vectorised expression and argmax'd — no iteration, no data
dependence (the split is a pure function of the budget), so the selection
costs a handful of FLOPs inside the jitted round and one compiled program
serves every contact length.  With ``b*`` fixed, the spend is Proposition 1
again at the new per-coordinate cost:

    k* = floor((B - 32) / (b* + lambda)),   clipped to [0, s].

Replacing the fixed ``u = 32`` of ``core.sparsify.bits_for_k`` with
``b* + lambda`` buys ``(32 + lambda)/(b* + lambda)`` x more coordinates per
contact window; the error-feedback memory absorbs the added quantisation
residual (``base.CompressorState``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.compression import quant as Q
from repro.compression.base import Compressor, CompressorState


def solve_kb(budget_bits, s: int, index_bits: int, b_grid):
    """Closed-form (k, b) split for one budget (traced-friendly).

    Returns (k_target, b): ``b`` maximises ``kappa(b) * (1 - eps(b))`` over
    the static grid, ``k_target = floor((B - 32)/(b + lambda))`` in [0, s].
    """
    bg = jnp.asarray(b_grid, jnp.float32)
    avail = jnp.maximum(budget_bits - Q.SCALE_BITS, 0.0)
    kappa = jnp.clip(avail / (float(s) * (bg + index_bits)), 0.0, 1.0)
    eps = (4.0 ** (-(bg - 1.0))) / 3.0
    b = bg[jnp.argmax(kappa * (1.0 - eps))]
    k = jnp.floor(jnp.clip(avail / (b + index_bits), 0.0, float(s)))
    return k, b


@dataclasses.dataclass(frozen=True)
class JointCompressor(Compressor):
    """MADS-joint: per-round (k*, b*) from the contact budget.

    ``per_layer=True`` replaces the single global split with per-leaf
    (k_l, b_l) pairs solved by greedy water-filling against the same
    budget — each leaf gets its own quantisation scale and width
    (``perlayer.solve_kb_per_leaf``; equations in the module docstring and
    core/README.md).  Not combined with the ``axis`` sharded contract:
    per-leaf amax/thresholds are single-host / global-view only.
    """

    b_grid: tuple = tuple(range(2, 17))
    per_layer: bool = False

    def compress(self, x, budget_bits, state: CompressorState):
        xt = self.combined(x, state)
        if self.per_layer:
            if self.axis is not None:
                raise NotImplementedError(
                    "per_layer budgets under a shard_map axis are not "
                    "supported; use the global-view pjit path"
                )
            from repro.compression.perlayer import compress_per_layer

            return compress_per_layer(self, xt, budget_bits, state)
        k_target, b = solve_kb(budget_bits, self.s, self.index_bits,
                               self.b_grid)
        return self.spend(xt, k_target, b, budget_bits, state, quantize=True)
