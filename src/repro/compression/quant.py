"""Stochastic uniform quantisation primitives (QSGD-style dithered rounding).

The wire format all quantising codecs share: values are scaled by a single
per-message step ``delta = amax / levels`` and stochastically rounded to the
integer grid ``q = clip(floor(v / delta + u), -levels, levels)`` with dither
``u ~ U[0, 1)`` — an unbiased estimator (``E[q * delta] = v``) whose
residual the error-feedback memory absorbs.  ``levels = 2^(b-1) - 1`` so a
signed value fits in ``b`` bits; the 32-bit float scale is counted once per
message (``SCALE_BITS``).

Dither is COUNTER-BASED, not stateful: ``dither_u01(seed, index)`` hashes
the (seed, global element index) pair with pure uint32 arithmetic
(lowbias32).  The jnp codecs, the pure-jnp kernel oracle, and the fused
Pallas kernel therefore make identical selection/rounding decisions — the
same element always draws the same dither for a given seed, independent of
blocking/sharding — so the quantised upload is bit-identical across
implementations (the error memory may differ by one FMA rounding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# one fp32 scale per compressed message, counted against the bit budget
SCALE_BITS = 32


def dither_u01(seed, idx):
    """U[0,1) dither for global element indices ``idx`` under ``seed``.

    ``seed``: scalar int32 (may be traced); ``idx``: int array of global
    element positions.  lowbias32 integer hash — identical results as jnp
    on any backend and inside a Pallas kernel body.
    """
    h = idx.astype(jnp.uint32) ^ seed.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return h.astype(jnp.float32) * jnp.float32(1.0 / 4294967296.0)


def quant_levels(b):
    """Signed integer grid half-width for a ``b``-bit value (b may be traced).

    ``2^(b-1) - 1`` magnitudes plus sign fit in ``b`` bits; floored at 1 so
    a degenerate b never divides by zero (callers gate on b >= 2 anyway).
    """
    return jnp.maximum(2.0 ** (jnp.asarray(b, jnp.float32) - 1.0) - 1.0, 1.0)


def quant_step(amax, levels):
    """Quantisation step ``delta`` mapping [-amax, amax] onto the grid."""
    return jnp.maximum(amax, 1e-12) / levels


def stochastic_round(x, step, levels, seed, base=0):
    """Dequantised stochastic quantisation of ``x`` (any shape).

    Returns ``q * step`` with ``q = clip(floor(x/step + u), -levels,
    levels)`` and dither ``u = dither_u01(seed, base + flat_index)`` —
    ``base`` is the leaf's global element offset so every element of a
    multi-leaf message draws distinct dither.  Unbiased for |x| <= amax.
    """
    xf = x.astype(jnp.float32)
    idx = base + jnp.arange(xf.size).reshape(xf.shape)
    u = dither_u01(jnp.asarray(seed), idx)
    q = jnp.clip(jnp.floor(xf / step + u), -levels, levels)
    return q * step


def seed_from_key(key):
    """Scalar int32 dither seed derived from a jax PRNG key."""
    return jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max,
                              dtype=jnp.int32)


def tree_amax(tree, axis: str | None = None):
    """Global max |value| across every leaf (one scale per message).

    ``axis``: optional mapped axis name (``shard_map``/``pmap``) over which
    the per-shard maxima are ``lax.pmax``-reduced, so every shard of a
    partitioned message derives the same quantisation step (max is
    order-independent, hence exact under any shard layout — the sharded
    contract in core/README.md).
    """
    amax = jnp.max(jnp.stack([
        jnp.max(jnp.abs(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)
    ]))
    if axis is not None:
        amax = jax.lax.pmax(amax, axis)
    return amax
