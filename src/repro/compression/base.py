"""The ``Compressor`` API: spend a contact-time bit budget on a gradient.

The paper's Proposition 1 converts the per-contact budget ``tau * A(p)``
(seconds x bits/s) into a top-k degree at fixed 32-bit values:
``k = tau A / (u + log2 s)``.  This subsystem generalises that single knob
to a family of codecs sharing one contract:

    payload, state, stats = compressor.compress(x, budget_bits, state)

* ``x``           — the fresh signal pytree (the device's accumulated
                    gradient ``g_n``; the codec adds its error-feedback
                    memory internally, matching Algorithm 1's
                    ``S(e_n + g_n)``).
* ``budget_bits`` — scalar realised contact capacity ``tau * A(p)``.
* ``state``       — a :class:`CompressorState` pytree: the error-feedback
                    memory plus a PRNG key for stochastic codecs.  Being a
                    plain pytree it threads through ``jax.vmap`` (devices)
                    and ``jax.lax.scan`` (rounds) unchanged.
* ``payload``     — the dense dequantised upload (what the MES adds);
                    shapes are static, unselected coordinates are zero.
* ``stats``       — ``{"k": #selected, "bits": realised payload bits,
                    "b": value bit-width used}`` scalars; the engines
                    assert/report ``bits <= budget_bits``.

Implementations (each a frozen dataclass, hashable, usable as a jit static
argument exactly like ``core.afl.Policy``):

* ``topk.TopKCompressor``    — Proposition 1 at configurable value width.
* ``topk.FixedKbCompressor`` — budget-clipped fixed (k, b) baseline.
* ``qsgd.QSGDCompressor``    — quantise-everything, bit-width from budget.
* ``joint.JointCompressor``  — the (k, b) split solved in closed form
                                (module docstring has the derivation);
                                ``per_layer=True`` solves (k_l, b_l) per
                                pytree leaf by greedy water-filling
                                (``perlayer.solve_kb_per_leaf``; equations
                                in core/README.md §per-layer budgets).

Every codec also runs inside the pjit distributed step
(``core/distributed.py``) — ``core.afl.compress_uploads`` is the shared
invocation, and the sharded-threshold contract (``strict_threshold``'s
``axis``/``s`` parameters, ``quant.tree_amax``'s ``axis``) is documented
in core/README.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.compression import quant as Q
from repro.core.sparsify import _strided_sample
from repro.kernels import ops


def strict_threshold(tree, k, *, method: str = "exact", sample: int = 65536,
                     axis: str | None = None, s: int | None = None):
    """|x| cutoff whose STRICT-above set has <= floor(k) elements.

    ``core.sparsify.tree_threshold`` returns the k-th order statistic for a
    ``>=`` mask — under magnitude ties (near-certain for bf16 gradients,
    whose 8-bit mantissa collapses values onto buckets) that mask selects
    the *whole* tied bucket and can wildly overshoot k.  The codecs instead
    take the (k+1)-th order statistic bumped one ulp, so the shared
    ``>=``-mask kernels implement ``> t``: with distinct magnitudes this
    selects exactly floor(k) elements (the same set as top-k), and ties can
    only UNDERSHOOT — making ``bits <= budget`` provable in exact mode
    rather than gated.  k >= s selects everything; k < 1 selects nothing.

    **Sharded contract** (core/README.md): when the signal is partitioned
    over a mapped axis (``shard_map``/``pmap``), pass ``axis`` and the
    GLOBAL flat size ``s`` — each shard contributes its local
    ``_strided_sample`` (exact mode: its full magnitudes) and the blocks
    are ``lax.all_gather``-ed over ``axis`` before the sort, so every
    device sorts the same gathered sample and agrees on the threshold
    bit-for-bit.  Shards must hold disjoint partitions of x.  Under plain
    pjit/GSPMD (global view) no axis is needed: the strided slice keeps
    shards local and only the small sample block is replicated.
    """
    leaves = jax.tree.leaves(tree)
    local = sum(l.size for l in leaves)
    if s is None:
        s = local
    kf = jnp.asarray(k, jnp.float32)
    if method == "exact":
        flat = jnp.concatenate(
            [jnp.abs(l.astype(jnp.float32)).reshape(-1) for l in leaves])
        if axis is not None:
            flat = jax.lax.all_gather(flat, axis, tiled=True)
        srt = jnp.sort(flat)[::-1]
        idx = jnp.clip(jnp.floor(kf).astype(jnp.int32), 0, s - 1)
    else:
        m_per = [max(int(sample * l.size / max(local, 1)), 16)
                 for l in leaves]
        flat = jnp.concatenate(
            [_strided_sample(l, m) for l, m in zip(leaves, m_per)])
        if axis is not None:
            flat = jax.lax.all_gather(flat, axis, tiled=True)
        srt = jnp.sort(flat)[::-1]
        frac = jnp.clip(kf / float(s), 0.0, 1.0)
        idx = jnp.clip(jnp.floor(frac * flat.size).astype(jnp.int32),
                       0, flat.size - 1)
    t = jnp.where(kf < 1.0, jnp.inf,
                  jnp.where(kf >= float(s), -jnp.inf, srt[idx]))
    return jnp.nextafter(t, jnp.inf)


class CompressorState(NamedTuple):
    """Codec state threaded through scan/vmap as a pytree.

    ``error``: error-feedback memory, same structure as the signal
    (Stich-style: residuals re-enter the next round's signal).
    ``key``: jax PRNG key advancing once per compress call (dither seeds).
    """

    error: Any
    key: jax.Array


def init_state(tree, key) -> CompressorState:
    """Zeroed error memory + the given PRNG key."""
    return CompressorState(
        error=jax.tree.map(jnp.zeros_like, tree), key=key
    )


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base codec: bit accounting constants + the error-feedback frame.

    ``s`` is the flat model size; every selected coordinate pays
    ``index_bits = ceil(log2 s)`` of position overhead on the wire
    (paper eq. 7c).  ``method``/``sample`` select the thresholding mode of
    ``core.sparsify`` (exact sort vs strided sample).

    ``axis`` opts into the sharded contract (core/README.md): inside a
    ``shard_map``/``pmap`` body where each device holds a disjoint shard
    of the signal, the threshold sample, the quantisation amax, and the
    selection count are all-reduced over the named axis
    (``all_gather``/``pmax``/``psum``), so every shard agrees on (k, b)
    and the budget gate fires identically everywhere.  Leave ``None``
    (default) for single-host use and for the pjit/GSPMD distributed step,
    whose global-view program needs no explicit collectives — there,
    shard-safety means ``method="sampled"`` (the strided sample never
    all-gathers the model; see ``core.sparsify._strided_sample``).
    """

    s: int
    method: str = "exact"
    sample: int = 65536
    error_feedback: bool = True
    axis: str | None = None

    @property
    def index_bits(self) -> int:
        return int(math.ceil(math.log2(max(self.s, 2))))

    # -- shared plumbing ----------------------------------------------------

    def combined(self, x, state: CompressorState):
        """x + error memory: the signal Algorithm 1 actually compresses."""
        return jax.tree.map(jnp.add, x, state.error)

    def next_state(self, error, state: CompressorState) -> CompressorState:
        """Advance the codec state with the EF residual produced alongside
        the payload (the fused op emits it in the same pass)."""
        if not self.error_feedback:
            error = jax.tree.map(jnp.zeros_like, error)
        return CompressorState(error=error,
                               key=jax.random.fold_in(state.key, 0))

    def dither_seed(self, state: CompressorState):
        """Per-call scalar dither seed (round/device-unique via the key)."""
        return Q.seed_from_key(state.key)

    def masked_payload(self, xt, t, *, quantize: bool, step=None, levels=None,
                       seed=None):
        """(payload, error, k_actual) across leaves under a global |x|
        threshold ``t``.

        ``quantize=False`` keeps raw values (bit-exact with
        ``core.sparsify.sparsify_tree``); ``quantize=True`` routes each
        leaf through the fused sparsify+quantize+EF op (Pallas on TPU, jnp
        oracle elsewhere — same selections either way, see
        ``compression.quant``).  The error tree comes out of the same
        pass; callers must not recompute it.
        """
        leaves, treedef = jax.tree.flatten(xt)
        ups, errs, count, base = [], [], jnp.float32(0.0), 0
        for leaf in leaves:
            if quantize:
                up, err, c = ops.sparsify_quantize_ef(
                    leaf, t, step, levels, seed, base=base
                )
            else:
                up, err, c = ops.sparsify_ef(leaf.reshape(-1), t)
                up = up.reshape(leaf.shape)
                err = err.reshape(leaf.shape)
            ups.append(up)
            errs.append(err)
            count = count + c
            base += leaf.size
        return (jax.tree.unflatten(treedef, ups),
                jax.tree.unflatten(treedef, errs), count)

    def spend(self, xt, k_target, b, budget_bits, state: CompressorState,
              *, quantize: bool):
        """Threshold at ~k_target, ship ``b``-bit values, bill the wire.

        The shared second half of every thresholding codec: global
        strict-above threshold (``strict_threshold`` — tie-immune, so
        exact mode can never overshoot floor(k_target)), fused
        payload/error/count, bit accounting ``k (b + log2 s) + scale``,
        and the budget gate: an upload whose realised bits would exceed
        the budget is withheld entirely (all-or-nothing, like the paper's
        full-upload baselines) and the EF memory keeps the round's mass
        for the next contact.  This makes ``stats["bits"] <= budget_bits``
        an invariant of every codec — provable in exact mode, gated under
        the ``sampled`` threshold estimate, whose count error makes the
        gate reachable.  So that it stays the exception, sampled mode
        first backs the target off by three standard errors of the
        m-sample quantile count (std of the realised k ~ sqrt(k s / m),
        the binomial error of the ~k m / s sample points above the
        threshold), capped at half the affordable k so short contacts
        still ship at reduced capacity instead of not at all.
        """
        if self.method == "sampled":
            m = float(min(self.sample, self.s))
            rel = jnp.minimum(
                3.0 * jnp.sqrt(float(self.s)
                               / (jnp.maximum(k_target, 1.0) * m)),
                0.5,
            )
            k_target = jnp.floor(jnp.maximum(k_target * (1.0 - rel), 0.0))
        t = strict_threshold(xt, k_target, method=self.method,
                             sample=self.sample, axis=self.axis, s=self.s)
        if quantize:
            levels = Q.quant_levels(b)
            step = Q.quant_step(Q.tree_amax(xt, axis=self.axis), levels)
            payload, error, k_actual = self.masked_payload(
                xt, t, quantize=True, step=step, levels=levels,
                seed=self.dither_seed(state),
            )
            overhead = Q.SCALE_BITS
        else:
            payload, error, k_actual = self.masked_payload(
                xt, t, quantize=False)
            overhead = 0
        if self.axis is not None:
            # shard-local popcounts -> the global k every device bills with
            k_actual = jax.lax.psum(k_actual, self.axis)
        bits = k_actual * (b + self.index_bits) + overhead * (k_actual > 0)
        feasible = (bits <= budget_bits).astype(jnp.float32)
        payload = jax.tree.map(
            lambda p: (p * feasible).astype(p.dtype), payload)
        error = jax.tree.map(
            lambda e, x_: jnp.where(feasible > 0, e, x_), error, xt)
        k_actual = k_actual * feasible
        stats = {"k": k_actual, "bits": bits * feasible,
                 "b": jnp.asarray(b, jnp.float32) * (k_actual > 0),
                 # the message's quantisation scale — what a receiver needs
                 # to reconstruct grid codes from the wire (wire.py); 1.0
                 # on the raw-f32 path
                 "step": (jnp.asarray(step, jnp.float32) if quantize
                          else jnp.float32(1.0))}
        return payload, self.next_state(error, state), stats

    # -- the contract -------------------------------------------------------

    def compress(self, x, budget_bits, state: CompressorState):
        raise NotImplementedError
