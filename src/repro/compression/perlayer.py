"""Per-layer bit budgets: the (k_l, b_l) split by greedy water-filling.

The global joint codec (``joint.solve_kb``) spends one (k, b) pair on the
whole message: a single quantisation scale and one keep-fraction, which
crushes small-magnitude leaves (a layernorm scale quantised against an
embedding's amax) and over-spends precision on leaves whose energy does not
warrant it.  Here the contact budget ``B = tau * A(p)`` is split across the
L pytree leaves, each getting its own scale, keep count, and bit-width.

**Score model** (the per-leaf refinement of joint.py's distortion model):
leaf l holds an energy fraction ``e_l`` of the signal (``e_l = ||x_l||^2 /
||x||^2``, data-dependent and traced); spending ``A_l`` bits on it at width
``b`` keeps at least a

    kappa_l(b) = min(1, A_l / (s_l (b + lambda)))        lambda = ceil(log2 s)

fraction of the leaf's coordinates (random-k lower bound), each surviving
quantisation with quality ``1 - eps(b)``, ``eps(b) = 4^{-(b-1)}/3``.  The
allocation objective is the retained useful energy

    score({A_l, b_l}) = sum_l  e_l * kappa_l(b_l) * (1 - eps(b_l)).

**Greedy water-filling.**  Below saturation the objective is linear in
``A_l`` with per-bit density ``(e_l/s_l) (1-eps(b))/(b+lambda)``; the width
factor is leaf-independent, so the marginal-density-optimal width

    b0 = argmax_b (1 - eps(b)) / (b + lambda)

is common to every unsaturated leaf and the linear program is a fractional
knapsack: fill leaves in decreasing energy-per-coordinate ``e_l/s_l`` until
the budget runs out, capping each at its b0-saturation cost
``s_l (b0 + lambda)``.  Budget left over once EVERY leaf is full (long
contacts) is spread size-proportionally and each leaf re-solves its width
in closed form on its own slice (``kappa_l = 1`` holds for a range of b;
the re-solve picks the largest affordable width — exactly joint.py's
saturation behaviour, now per leaf).  One sort + cumsum, fully traced, no
iteration.

**Never worse than the uniform per-leaf split.**  The single-(k, b)
strategy expressed per leaf (``uniform_split``: size-proportional budget
shares, which make every leaf's kappa and width coincide) is a feasible
point of the same program, and the solver returns whichever of {greedy,
uniform} scores higher under ``split_score`` — so the water-filled
allocation is >= that baseline by construction (property-tested in
tests/test_property.py).  Note this compares within the per-leaf regime:
both sides pay one fp32 scale per leaf.  The actual global
``JointCompressor`` pays a single 32-bit scale for the whole message, so
at budgets within ~32 L bits of empty the global codec can still ship
more — the scale overhead is the price of per-leaf ranges, not a solver
artefact.

**Bit accounting.**  Each shipping leaf pays its own fp32 scale, so the
solver works against ``avail = B - 32 L`` and guarantees

    sum_l k_l (b_l + lambda) + 32 * |{l : k_l > 0}|  <=  B.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import quant as Q
from repro.kernels import ops


def eps_b(b):
    """Quantisation-noise energy fraction at width b (see joint.py)."""
    return (4.0 ** (-(jnp.asarray(b, jnp.float32) - 1.0))) / 3.0


def leaf_energies(leaves):
    """Per-leaf signal energies ||x_l||^2 (unnormalised, traced)."""
    return jnp.stack(
        [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves]
    )


def split_score(k, b, sizes, energies):
    """Retained-useful-energy score of a realised per-leaf allocation.

    ``sum_l e_l * min(k_l/s_l, 1) * (1 - eps(b_l))`` with e_l the
    normalised energy fractions — the shared yardstick for comparing the
    greedy and uniform splits (and the property tests' oracle).
    """
    sz = jnp.asarray(sizes, jnp.float32)
    e = energies / jnp.maximum(jnp.sum(energies), 1e-30)
    return jnp.sum(e * jnp.clip(k / sz, 0.0, 1.0) * (1.0 - eps_b(b)))


def _solve_avail(avail, sz, index_bits, bg):
    """Vectorised closed-form (k, b) per leaf given each leaf's own budget
    slice (joint.solve_kb without the scale subtraction, batched over L)."""
    lam = float(index_bits)
    kappa = jnp.clip(
        avail[:, None] / (sz[:, None] * (bg[None, :] + lam)), 0.0, 1.0
    )
    score = kappa * (1.0 - eps_b(bg))[None, :]
    b = bg[jnp.argmax(score, axis=1)]
    k = jnp.floor(jnp.clip(avail / (b + lam), 0.0, sz))
    return k, b


def uniform_split(budget_bits, sizes, index_bits, b_grid):
    """The single-(k, b) strategy expressed as a per-leaf allocation.

    Size-proportional shares of ``avail = B - 32 L`` give every leaf the
    same keep-fraction (``kappa_l = avail/(s (b+lambda))``), so each
    leaf's closed-form re-solve lands on one common width — the
    single-split strategy under per-leaf scale accounting, and the
    baseline the greedy solver must never score below.  (The actual
    global ``JointCompressor`` pays one scale total — 32 (L - 1) bits
    fewer overhead; see the module docstring.)
    """
    sz = jnp.asarray(np.asarray(sizes, np.float32))
    bg = jnp.asarray(b_grid, jnp.float32)
    avail = jnp.maximum(
        jnp.asarray(budget_bits, jnp.float32) - Q.SCALE_BITS * len(sizes), 0.0
    )
    return _solve_avail(avail * sz / jnp.sum(sz), sz, index_bits, bg)


def solve_kb_per_leaf(budget_bits, sizes, energies, index_bits, b_grid):
    """Greedy water-filling (k_l, b_l) split of one contact budget.

    ``sizes``: static per-leaf flat sizes; ``energies``: traced per-leaf
    signal energies (any positive scale); returns float (L,) arrays
    ``(k, b)`` with ``b`` drawn from ``b_grid`` and the bit accounting of
    the module docstring guaranteed.
    """
    sz = jnp.asarray(np.asarray(sizes, np.float32))
    num = len(sizes)
    bg = jnp.asarray(b_grid, jnp.float32)
    lam = float(index_bits)
    avail = jnp.maximum(
        jnp.asarray(budget_bits, jnp.float32) - Q.SCALE_BITS * num, 0.0
    )

    # marginal-density-optimal width: common to every unsaturated leaf
    b0 = bg[jnp.argmax((1.0 - eps_b(bg)) / (bg + lam))]

    # fractional-knapsack fill in decreasing energy-per-coordinate order
    density = energies / jnp.maximum(jnp.sum(energies), 1e-30) / sz
    order = jnp.argsort(-density)
    cap = sz * (b0 + lam)  # b0-saturation cost per leaf
    cap_sorted = cap[order]
    cum = jnp.cumsum(cap_sorted)
    alloc_sorted = jnp.clip(avail - (cum - cap_sorted), 0.0, cap_sorted)
    alloc = jnp.zeros_like(cap).at[order].set(alloc_sorted)
    # leftover exists only once every leaf is b0-saturated: spread it
    # size-proportionally and let the per-leaf re-solve buy wider values
    leftover = jnp.maximum(avail - jnp.sum(alloc), 0.0)
    alloc = alloc + leftover * sz / jnp.sum(sz)

    k_g, b_g = _solve_avail(alloc, sz, index_bits, bg)

    # constructive guarantee: never score below the global split
    k_u, b_u = uniform_split(budget_bits, sizes, index_bits, b_grid)
    greedy_wins = (
        split_score(k_g, b_g, sz, energies)
        >= split_score(k_u, b_u, sz, energies)
    )
    k = jnp.where(greedy_wins, k_g, k_u)
    b = jnp.where(greedy_wins, b_g, b_u)
    return k, b


def compress_per_layer(comp, xt, budget_bits, state):
    """The per-leaf compression pass behind ``JointCompressor(per_layer=
    True)`` — ``base.Compressor.spend`` unrolled leaf-by-leaf.

    Each leaf gets its own strict threshold (with the sampled-mode
    three-standard-error backoff of ``spend``, scaled to the leaf's sample
    share), its own quantisation scale, and its solver-assigned width; the
    dither counter stays message-global (``base`` offsets), so a coordinate
    draws the same dither as in the single-split codec.  The budget gate is
    all-or-nothing on the summed realised bits, exactly like ``spend``.
    """
    from repro.compression.base import strict_threshold

    leaves, treedef = jax.tree.flatten(xt)
    sizes = tuple(int(l.size) for l in leaves)
    k_l, b_l = solve_kb_per_leaf(
        budget_bits, sizes, leaf_energies(leaves), comp.index_bits,
        comp.b_grid,
    )
    seed = comp.dither_seed(state)
    lam = float(comp.index_bits)
    ups, errs = [], []
    bits = jnp.float32(0.0)
    k_total = jnp.float32(0.0)
    b_weighted = jnp.float32(0.0)
    base = 0
    for i, leaf in enumerate(leaves):
        ki = k_l[i]
        m_leaf = max(min(int(comp.sample * sizes[i] / max(comp.s, 1)),
                         sizes[i]), 16)
        if comp.method == "sampled":
            rel = jnp.minimum(
                3.0 * jnp.sqrt(float(sizes[i])
                               / (jnp.maximum(ki, 1.0) * float(m_leaf))),
                0.5,
            )
            ki = jnp.floor(jnp.maximum(ki * (1.0 - rel), 0.0))
        t = strict_threshold(leaf, ki, method=comp.method, sample=m_leaf)
        levels = Q.quant_levels(b_l[i])
        step = Q.quant_step(Q.tree_amax(leaf), levels)
        up, err, cnt = ops.sparsify_quantize_ef(
            leaf, t, step, levels, seed, base=base
        )
        ups.append(up)
        errs.append(err)
        bits = bits + cnt * (b_l[i] + lam) + Q.SCALE_BITS * (cnt > 0)
        k_total = k_total + cnt
        b_weighted = b_weighted + cnt * b_l[i]
        base += leaf.size
    feasible = (bits <= budget_bits).astype(jnp.float32)
    payload = jax.tree.unflatten(
        treedef, [(u * feasible).astype(u.dtype) for u in ups]
    )
    error = jax.tree.unflatten(
        treedef,
        [jnp.where(feasible > 0, e, x_) for e, x_ in zip(errs, leaves)],
    )
    k_total = k_total * feasible
    stats = {
        "k": k_total,
        "bits": bits * feasible,
        # realised selection-weighted mean width (per-leaf widths differ)
        "b": jnp.where(
            k_total > 0, b_weighted / jnp.maximum(k_total, 1.0), 0.0
        ) * feasible,
        # per-leaf scales don't fit the single-step wire header: 0 tells
        # the encoder to fall back to raw-f32 codes (wire.py)
        "step": jnp.float32(0.0),
    }
    return payload, comp.next_state(error, state), stats
