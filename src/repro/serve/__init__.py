"""Streaming ingestion path for compressed client uploads.

The simulation engines (``core/afl.py``, ``experiments/scan_engine.py``,
``core/distributed.py``) aggregate a whole round of uploads as one tensor
contraction — fine when the scenario engine *generates* the uploads.  A
deployed MES instead receives them one at a time off the network.  This
package is that server:

* ``queue``     — ``ArrivalBuffer``: bounded arrival queue with counted
  backpressure (reject or defer; nothing is ever dropped silently).
* ``aggregate`` — ``make_fused_ingest``: decompress + staleness-weighted
  aggregation over a padded batch of wire payloads as ONE jitted op,
  bit-identical to ``afl_round``'s aggregation (tests/test_serve.py).
* ``server``    — ``IngestServer``: buffer + fused op + serve telemetry
  registry + optional mesh sharding, with a one-fetch snapshot.

Wire format: ``repro.compression.wire``.  Staleness family:
``repro.core.afl.StalenessWeight`` (shared with the engines via
``Policy``).  See README.md here for the contracts.
"""
from repro.serve.aggregate import make_fused_ingest
from repro.serve.queue import ArrivalBuffer
from repro.serve.server import IngestServer

__all__ = ["ArrivalBuffer", "IngestServer", "make_fused_ingest"]
