"""``IngestServer`` — the streaming MES: arrival buffer + fused ingest.

Composes the pieces into the serving loop a deployed aggregator runs:
clients ``submit`` wire payloads (bounded queue, counted backpressure),
``step`` drains up to one batch through the fused decompress+aggregate
op, and ``snapshot`` folds the host-side queue accounting into the
device-resident serve registry state for the run's ONE telemetry fetch.

Mesh-aware: pass a ``Mesh`` (e.g. from ``launch.mesh.make_client_mesh``)
and every packed batch is placed with ``core.distributed.ingest_shardings``
— the batch axis shards over ``data``, the global model replicates, and
GSPMD lowers the weighted client contraction to the same hierarchical
all-reduce as the distributed train step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compression.wire import WirePayload, pack_batch
from repro.core.afl import StalenessWeight
from repro.serve.aggregate import make_fused_ingest
from repro.serve.queue import ArrivalBuffer
from repro.telemetry.metrics import MetricRegistry, serve_registry
from repro.telemetry.tracing import PhaseTracer

__all__ = ["IngestServer"]


class IngestServer:
    """Bounded-queue ingestion front-end over the fused aggregation op."""

    def __init__(self, w, *, num_devices: int, batch: int, max_k: int,
                 staleness: StalenessWeight = StalenessWeight(),
                 queue_capacity: Optional[int] = None,
                 queue_policy: str = "reject",
                 registry: Optional[MetricRegistry] = None,
                 tracer: Optional[PhaseTracer] = None,
                 mesh=None, mode: str = "parity"):
        self.batch = int(batch)
        self.max_k = int(max_k)
        self.num_devices = int(num_devices)
        self.staleness = staleness
        self.s = sum(int(jnp.size(l)) for l in jax.tree.leaves(w))
        self.registry = serve_registry() if registry is None else registry
        self.tracer = tracer or PhaseTracer()
        self.buffer = ArrivalBuffer(
            capacity=queue_capacity if queue_capacity is not None
            else 4 * self.batch,
            policy=queue_policy)
        self.mesh = mesh
        self._shardings = None
        if mesh is not None:
            from repro.core.distributed import ingest_shardings
            if self.batch % mesh.devices.size:
                raise ValueError(
                    f"batch={self.batch} not divisible by mesh size "
                    f"{mesh.devices.size}")
            self._shardings = ingest_shardings(mesh)
            w = jax.device_put(w, self._shardings["w"])
        self.w = w
        self.tstate = self.registry.init_state()
        self.rnd = 0  # server-side model version counter
        self._ingest = make_fused_ingest(
            w, batch=self.batch, max_k=self.max_k,
            num_devices=self.num_devices, staleness=staleness,
            registry=self.registry, mode=mode)

    # -- producer ------------------------------------------------------------

    def submit(self, payload: WirePayload) -> bool:
        """Offer one upload; ``False`` means backpressure (counted)."""
        return self.buffer.offer(payload)

    # -- consumer ------------------------------------------------------------

    def step(self) -> int:
        """Drain up to one batch through the fused op; returns the number
        of uploads aggregated (0 leaves all state untouched — an empty
        batch must not advance the model version)."""
        items = self.buffer.take(self.batch)
        if not items:
            return 0
        with self.tracer.span("serve.pack", n=len(items)):
            packed = pack_batch(items, s=self.s, max_k=self.max_k,
                                batch=self.batch, server_round=self.rnd)
            if self._shardings is not None:
                packed = {k: jax.device_put(v, self._shardings["batch"])
                          for k, v in packed.items()}
        with self.tracer.span("serve.ingest", n=len(items)) as tr:
            self.w, self.tstate = self._ingest(self.w, packed, self.tstate)
            tr.fence(self.w)
        self.rnd += 1
        return len(items)

    def drain(self) -> int:
        """Step until the buffer is empty; returns uploads aggregated."""
        total = 0
        while len(self.buffer):
            total += self.step()
        return total

    # -- accounting ----------------------------------------------------------

    def snapshot(self) -> dict:
        """Queue counters + device registry state -> one host fetch."""
        self.buffer.check_invariant()
        c = self.buffer.counters()
        st = self.registry.update(
            self.tstate,
            counters={k: float(c[k]) for k in
                      ("received", "accepted", "rejected", "deferred")},
            gauges={"queue_depth": float(c["depth"]),
                    "queue_peak": float(c["peak"])},
        )
        return self.registry.fetch(st)
