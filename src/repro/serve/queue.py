"""Bounded arrival buffer with counted backpressure.

The server's admission queue: uploads are ``offer``-ed as they arrive and
``take``-n in FIFO order by the fused ingest step.  When the buffer is at
capacity the offer fails *loudly* — the caller is told, and one of the
backpressure counters is bumped — so the accounting invariant

    received == accepted + rejected + deferred
    accepted == taken + depth

holds at every instant (tests/test_serve.py enforces it).  Two
backpressure policies, chosen at construction:

* ``"reject"`` — the upload is refused for good; the client must
  recompress against a fresher model (its round counter moved on).
* ``"defer"``  — the upload is pushed back to the client for retry;
  the payload is unchanged, only its staleness grows.

The distinction is bookkeeping, not mechanics — both return ``False``
from ``offer`` — but they age differently (a deferred payload re-arrives
with a larger ``delta_tau``), so telemetry counts them separately.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, List, Optional

__all__ = ["ArrivalBuffer"]

_POLICIES = ("reject", "defer")


class ArrivalBuffer:
    """FIFO queue of wire payloads with a hard capacity."""

    def __init__(self, capacity: int, policy: str = "reject"):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if policy not in _POLICIES:
            raise ValueError(f"policy must be one of {_POLICIES}, "
                             f"got {policy!r}")
        self.capacity = int(capacity)
        self.policy = policy
        self._q: deque = deque()
        self.received = 0
        self.accepted = 0
        self.rejected = 0
        self.deferred = 0
        self.taken = 0
        self.peak = 0

    # -- producer side -------------------------------------------------------

    def offer(self, item) -> bool:
        """Admit one upload; ``False`` (+ a counter) when full."""
        self.received += 1
        if len(self._q) >= self.capacity:
            if self.policy == "reject":
                self.rejected += 1
            else:
                self.deferred += 1
            return False
        self._q.append(item)
        self.accepted += 1
        self.peak = max(self.peak, len(self._q))
        return True

    def offer_all(self, items: Iterable) -> int:
        """Offer each item; returns how many were admitted."""
        return sum(1 for it in items if self.offer(it))

    # -- consumer side -------------------------------------------------------

    def take(self, k: Optional[int] = None) -> List:
        """Pop up to ``k`` items FIFO (all queued items if ``k`` is None)."""
        n = len(self._q) if k is None else min(int(k), len(self._q))
        out = [self._q.popleft() for _ in range(n)]
        self.taken += n
        return out

    # -- accounting ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def counters(self) -> dict:
        return {"received": self.received, "accepted": self.accepted,
                "rejected": self.rejected, "deferred": self.deferred,
                "taken": self.taken, "depth": self.depth, "peak": self.peak}

    def check_invariant(self) -> None:
        """Raise if any upload went unaccounted for."""
        if self.received != self.accepted + self.rejected + self.deferred:
            raise AssertionError(
                f"arrival accounting broken: received={self.received} != "
                f"accepted={self.accepted} + rejected={self.rejected} + "
                f"deferred={self.deferred}")
        if self.accepted != self.taken + self.depth:
            raise AssertionError(
                f"queue accounting broken: accepted={self.accepted} != "
                f"taken={self.taken} + depth={self.depth}")
