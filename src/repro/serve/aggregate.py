"""Fused batched decompress + staleness-weighted aggregation.

One jitted program takes a padded batch of wire payloads and applies them
to the global model: dequantise the codes (``wire.decode_values``),
scatter the sparse coordinates into a dense per-upload block, and mix
with the FedAsync ``alpha * s(delta_tau)`` weights
(``core.afl.StalenessWeight`` — the SAME object the engines carry on
``Policy``, so server and simulator share the rule by construction).

Two aggregation kernels, chosen at build time:

* ``mode="parity"`` (default) — scatter to a dense ``(B, s)`` block, then
  apply per-leaf exactly ``afl_round``'s expression
  ``w - (tensordot(mix, up, axes=(0,0)) / N).astype(w.dtype)``.  Same
  values, same contraction shape per leaf → the SAME reduction XLA lowers
  for the engines, which is what makes a batch of B uploads bit-identical
  to one ``afl_round`` over those B devices (tests/test_serve.py, all
  four codecs).
* ``mode="scatter"`` — weight the decoded values per row and scatter-add
  straight into one ``(s,)`` accumulator, skipping the ``(B, s)`` dense
  intermediate.  O(B·K) work instead of O(B·s); the result is equal up
  to float summation order (same exact answer whenever no two uploads in
  the batch ship the same coordinate).

Telemetry rides inside the op: pass a ``serve_registry()`` and its state
is updated per batch with zero extra host round-trips.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.afl import StalenessWeight
from repro.telemetry.metrics import MetricRegistry, record_ingest

__all__ = ["make_fused_ingest"]

_MODES = ("parity", "scatter")


def make_fused_ingest(w_template, *, batch: int, max_k: int,
                      num_devices: int,
                      staleness: StalenessWeight = StalenessWeight(),
                      registry: Optional[MetricRegistry] = None,
                      mode: str = "parity"):
    """Build the jitted ingest step for a fixed model/batch geometry.

    ``w_template`` fixes the pytree structure and leaf shapes of the
    global weights (the padded flat size ``s`` and the per-leaf slicing
    are compile-time constants).  ``num_devices`` is the paper's ``N`` —
    the MES averages over the population, not over the batch.

    Returns ``ingest(w, packed, tstate) -> (w_new, tstate')`` where
    ``packed`` is a ``wire.pack_batch`` dict and ``tstate`` the registry
    state (pass ``{}`` when ``registry`` is None).
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    leaves, treedef = jax.tree.flatten(w_template)
    shapes = [l.shape for l in leaves]
    sizes = [int(jnp.size(l)) for l in leaves]
    offsets = [sum(sizes[:i]) for i in range(len(sizes))]
    s = sum(sizes)
    from repro.compression.wire import decode_values  # avoid import cycle

    def ingest(w, packed, tstate):
        coords = jnp.asarray(packed["coords"], jnp.int32)
        vals = decode_values(packed["codes"], packed["step"], packed["b"])
        mask = jnp.asarray(packed["mask"], jnp.float32)
        dtau = jnp.asarray(packed["dtau"], jnp.float32)
        # the engines' mixing rule, verbatim (afl_round): identity family
        # drops the multiply at trace time
        mix = mask if staleness.is_identity \
            else mask * staleness.weight(dtau)
        w_leaves = jax.tree.leaves(w)
        if mode == "parity":
            rows = jnp.arange(batch, dtype=jnp.int32)[:, None]
            dense = jnp.zeros((batch, s), jnp.float32)
            dense = dense.at[rows, coords].add(vals, mode="drop")
            new = []
            for leaf, off, size, shape in zip(w_leaves, offsets, sizes,
                                              shapes):
                up = dense[:, off:off + size].reshape((batch,) + shape)
                new.append(leaf - (
                    jnp.tensordot(mix, up.astype(jnp.float32), axes=(0, 0))
                    / num_devices
                ).astype(leaf.dtype))
        else:
            wvals = vals * mix[:, None]
            acc = jnp.zeros((s,), jnp.float32)
            acc = acc.at[coords.reshape(-1)].add(wvals.reshape(-1),
                                                 mode="drop")
            new = []
            for leaf, off, size, shape in zip(w_leaves, offsets, sizes,
                                              shapes):
                up = acc[off:off + size].reshape(shape)
                new.append(leaf - (up / num_devices).astype(leaf.dtype))
        w_new = jax.tree.unflatten(treedef, new)
        if registry is not None:
            tstate = record_ingest(
                registry, tstate, mask=mask, dtau=dtau,
                bits=jnp.asarray(packed["bits"], jnp.float32), weights=mix)
        return w_new, tstate

    return jax.jit(ingest)
