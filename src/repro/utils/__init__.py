"""Shared utilities: pytree math, bit accounting, logging, rng streams."""
from repro.utils.tree import (
    tree_add,
    tree_scale,
    tree_sub,
    tree_zeros_like,
    tree_norm,
    tree_dot,
    global_norm,
    tree_size,
    flatten_concat,
    unflatten_like,
)
from repro.utils.logging import get_logger

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
    "tree_norm",
    "tree_dot",
    "global_norm",
    "tree_size",
    "flatten_concat",
    "unflatten_like",
    "get_logger",
]
