"""Minimal structured logger (stdout, flush-friendly for tee'd benchmark runs).

Environment knobs:

* ``REPRO_LOG_FORMAT=json`` — one JSON object per line (``ts``, ``logger``,
  ``level``, ``msg``) instead of the human-readable format, so benchmark
  and sweep output can be ingested alongside the telemetry JSONL sinks.
* ``REPRO_LOG_LEVEL=DEBUG|INFO|WARNING|ERROR`` — root level for every
  ``repro.*`` logger (default INFO).
"""
from __future__ import annotations

import json
import logging
import os
import sys

_FORMAT = "%(asctime)s %(name)s %(levelname)s %(message)s"


class _JsonFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": round(record.created, 3),
            "logger": record.name,
            "level": record.levelname,
            "msg": record.getMessage(),
        }
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out)


def _make_formatter() -> logging.Formatter:
    if os.environ.get("REPRO_LOG_FORMAT", "").lower() == "json":
        return _JsonFormatter()
    return logging.Formatter(_FORMAT, datefmt="%H:%M:%S")


def _level() -> int:
    name = os.environ.get("REPRO_LOG_LEVEL", "INFO").upper()
    return getattr(logging, name, logging.INFO)


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(_make_formatter())
        logger.addHandler(handler)
        logger.setLevel(_level())
        logger.propagate = False
    return logger
