"""Minimal structured logger (stdout, flush-friendly for tee'd benchmark runs)."""
from __future__ import annotations

import logging
import sys

_FORMAT = "%(asctime)s %(name)s %(levelname)s %(message)s"


def get_logger(name: str = "repro") -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        handler = logging.StreamHandler(sys.stdout)
        handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
        logger.addHandler(handler)
        logger.setLevel(logging.INFO)
        logger.propagate = False
    return logger
