"""Pytree arithmetic helpers used across the AFL core.

Every federated-state object (cumulative gradients g_n, error memory e_n,
client models w_n) is a pytree with the same structure as the model params;
these helpers implement the vector-space operations of Algorithm 1 without
materialising flattened copies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return sum(jax.tree.leaves(leaves))


def tree_norm(a):
    """Squared L2 norm of a pytree (the paper's ||x_n||^2)."""
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return sum(jax.tree.leaves(leaves))


def global_norm(a):
    return jnp.sqrt(tree_norm(a))


def tree_size(a) -> int:
    """Total number of scalar parameters s (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def flatten_concat(a):
    """Concatenate all leaves into a single flat vector (simulation mode)."""
    leaves = jax.tree.leaves(a)
    return jnp.concatenate([jnp.ravel(x) for x in leaves]) if leaves else jnp.zeros((0,))


def unflatten_like(vec, ref):
    """Inverse of flatten_concat given a reference pytree."""
    leaves, treedef = jax.tree.flatten(ref)
    out, off = [], 0
    for leaf in leaves:
        n = leaf.size
        out.append(jnp.reshape(vec[off : off + n], leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
