from repro.mobility.contact import (
    ContactProcess,
    contact_schedule,
    intervals_to_rounds,
)
from repro.mobility.waypoint import RandomWaypoint, measure_contact_stats

__all__ = [
    "ContactProcess",
    "contact_schedule",
    "intervals_to_rounds",
    "RandomWaypoint",
    "measure_contact_stats",
]
