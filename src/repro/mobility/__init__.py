from repro.mobility.contact import ContactProcess, contact_schedule
from repro.mobility.waypoint import RandomWaypoint, measure_contact_stats

__all__ = ["ContactProcess", "contact_schedule", "RandomWaypoint", "measure_contact_stats"]
