"""Random-waypoint mobility simulator (paper §VI-A-3, Fig. 4).

MES + N devices move in a square area; a device is "in contact" while
within the transmission range of the MES.  Used to validate the inverse
relationship between speed and contact / inter-contact times
(c = C/v, lambda = L/v) that Corollary 1 builds on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RandomWaypoint:
    num_devices: int = 20
    area: float = 1000.0  # m (square side)
    comm_range: float = 100.0  # m
    mean_speed: float = 10.0  # m/s
    pause_max: float = 5.0  # s
    dt: float = 1.0  # s
    seed: int = 0

    def simulate(self, duration: float):
        """Returns in_range: (steps, num_devices) bool (device-MES contact)."""
        rng = np.random.default_rng(self.seed)
        steps = int(duration / self.dt)
        n = self.num_devices + 1  # entity 0 is the MES
        pos = rng.uniform(0, self.area, (n, 2))
        dest = rng.uniform(0, self.area, (n, 2))
        speed = rng.uniform(0.5 * self.mean_speed, 1.5 * self.mean_speed, n)
        pause = np.zeros(n)
        out = np.zeros((steps, self.num_devices), bool)
        for t in range(steps):
            vec = dest - pos
            dist = np.linalg.norm(vec, axis=1)
            arrived = dist < speed * self.dt
            moving = (pause <= 0) & ~arrived
            step_vec = np.zeros_like(pos)
            nz = dist > 1e-9
            step_vec[nz] = vec[nz] / dist[nz, None]
            pos[moving] += step_vec[moving] * (speed[moving] * self.dt)[:, None]
            # arrivals: pause then pick a new waypoint
            newly = arrived & (pause <= 0)
            pause[newly] = rng.uniform(0, self.pause_max, newly.sum())
            pos[newly] = dest[newly]
            repick = (pause > 0)
            pause[repick] -= self.dt
            done_pausing = repick & (pause <= 0)
            if done_pausing.any():
                dest[done_pausing] = rng.uniform(0, self.area, (done_pausing.sum(), 2))
                speed[done_pausing] = rng.uniform(
                    0.5 * self.mean_speed, 1.5 * self.mean_speed, done_pausing.sum()
                )
            d2mes = np.linalg.norm(pos[1:] - pos[0], axis=1)
            out[t] = d2mes < self.comm_range
        return out


def measure_contact_stats(in_range: np.ndarray, dt: float = 1.0,
                          drop_truncated: bool = True):
    """Mean contact & inter-contact durations from an in-range trace.

    The first and last segments of each device's trace are censored by the
    observation window (their true start/end falls outside it), so counting
    them biases both means low.  They are dropped by default; pass
    ``drop_truncated=False`` for the seed's biased estimator.
    """
    contacts, gaps = [], []
    for n in range(in_range.shape[1]):
        x = in_range[:, n].astype(np.int8)
        changes = np.flatnonzero(np.diff(x))
        bounds = np.concatenate([[0], changes + 1, [len(x)]])
        for i in range(len(bounds) - 1):
            if drop_truncated and (i == 0 or i == len(bounds) - 2):
                continue  # window-truncated: duration is a lower bound only
            seg = x[bounds[i]]
            length = (bounds[i + 1] - bounds[i]) * dt
            (contacts if seg else gaps).append(length)
    mc = float(np.mean(contacts)) if contacts else 0.0
    mg = float(np.mean(gaps)) if gaps else float("inf")
    return mc, mg
