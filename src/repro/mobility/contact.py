"""Exponential contact / inter-contact process (paper §III-B).

Each device alternates contact periods tau ~ Exp(mean c_n) and
inter-contact gaps t ~ Exp(mean lambda_n).  Rounds have duration delta;
zeta_n^(r) = 1 in the round where a contact event begins (one upload
opportunity per contact, with the full sampled contact duration tau
available for the transfer) — matching the paper's abstraction where
tau_n^(r) bounds the upload bits via tau * A.

With speed coupling (Lemma/Corollary setting): c = C / v, lambda = L / v.

``sample_rounds`` is fully vectorized (batched renewal sampling across
devices + a flat interval->round scatter); the seed per-device Python loop
is kept as ``sample_rounds_loop`` for the equivalence test and the
``benchmarks/bench_mobility.py`` speedup entry.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def intervals_to_rounds(dev, start, dur, num_devices: int, rounds: int,
                        delta: float):
    """Map contact intervals to per-round (zeta, tau), Algorithm-1 semantics.

    dev / start / dur: flat arrays of contact intervals, time-ordered within
    each device.  A device is in contact for every round its interval
    overlaps; tau is the full interval duration in the round where the
    contact begins and the remaining duration from the round boundary in
    continuation rounds.  When two intervals touch the same round (a gap
    shorter than delta), the earlier interval claims it — identical to the
    sequential loop's first-writer-wins rule.

    Returns (zeta, tau): (rounds, num_devices) int32 / float32.
    """
    zeta = np.zeros(rounds * num_devices, np.int32)
    tau = np.zeros(rounds * num_devices, np.float32)
    horizon = rounds * delta
    keep = (np.asarray(start) < horizon) & (np.asarray(dur) > 0)
    dev = np.asarray(dev)[keep]
    start = np.asarray(start, np.float64)[keep]
    dur = np.asarray(dur, np.float64)[keep]
    if len(dev) == 0:
        return (zeta.reshape(rounds, num_devices),
                tau.reshape(rounds, num_devices))

    end = start + dur
    r0 = (start / delta).astype(np.int64)
    # last covered round: ceil(end/delta) - 1, so a contact ending exactly on
    # a round boundary does not claim the next round with tau = 0 (discrete
    # traces hit boundaries constantly; the continuous model almost never)
    r1 = np.ceil(np.minimum(end, horizon - 1e-9) / delta).astype(np.int64) - 1
    r1 = np.minimum(np.maximum(r1, r0), rounds - 1)
    length = r1 - r0 + 1

    # expand each interval to its covered rounds (flat repeat + offset trick)
    iid = np.repeat(np.arange(len(length)), length)
    offs = np.arange(length.sum()) - np.repeat(np.cumsum(length) - length, length)
    rr = r0[iid] + offs
    tau_cand = np.where(offs == 0, dur[iid], end[iid] - rr * delta)
    flat = rr * num_devices + dev[iid]

    # first interval to reach a (round, device) cell wins: scatter in reverse
    # time order — duplicate fancy indices keep the LAST write, which after
    # reversal is the earliest interval (the sequential loop's rule)
    zeta[flat[::-1]] = 1
    tau[flat[::-1]] = tau_cand[::-1]
    return (zeta.reshape(rounds, num_devices),
            tau.reshape(rounds, num_devices))


@dataclasses.dataclass
class ContactProcess:
    num_devices: int
    mean_contact: float  # c_n
    mean_intercontact: float  # lambda_n
    round_duration: float  # delta
    seed: int = 0

    @classmethod
    def from_speed(cls, num_devices, speed, contact_const, intercontact_const,
                   round_duration, seed=0):
        v = max(speed, 1e-6)
        return cls(
            num_devices,
            mean_contact=contact_const / v,
            mean_intercontact=intercontact_const / v,
            round_duration=round_duration,
            seed=seed,
        )

    def sample_rounds(self, rounds: int):
        """Returns (zeta, tau): each (rounds, num_devices).

        Per Algorithm 1's zeta_n^(r): a device is "in contact in round r" for
        EVERY round its contact period overlaps.  tau[r, n] is the upload
        window available in that round: the full sampled contact duration in
        the round where the contact begins (the paper's tau ~ Exp(c)), and
        the remaining duration from the round boundary for continuation
        rounds of a long contact.

        Vectorized: all renewal cycles are drawn in one batch across devices,
        then contact intervals are scattered to rounds in one pass.
        """
        rng = np.random.default_rng(self.seed)
        n, delta = self.num_devices, self.round_duration
        horizon = rounds * delta
        c, lam = self.mean_contact, self.mean_intercontact

        # start in contact or in a gap, per renewal stationarity
        sic = rng.random(n) < c / (c + lam)
        m = max(4, int(horizon / (c + lam) * 1.6) + 4)
        while True:
            cdur = np.maximum(rng.exponential(c, (n, m)), 1e-9)
            gdur = np.maximum(rng.exponential(lam, (n, m)), 1e-9)
            dur = np.empty((n, 2 * m))
            dur[:, 0::2] = np.where(sic[:, None], cdur, gdur)
            dur[:, 1::2] = np.where(sic[:, None], gdur, cdur)
            if dur.sum(axis=1).min() >= horizon:
                break
            m *= 2  # rare: a device's cycles fell short of the horizon

        end = np.cumsum(dur, axis=1)
        start = end - dur
        is_contact = np.empty((n, 2 * m), bool)
        is_contact[:, 0::2] = sic[:, None]
        is_contact[:, 1::2] = ~sic[:, None]
        sel = is_contact & (start < horizon)
        dev = np.broadcast_to(np.arange(n)[:, None], sel.shape)[sel]
        return intervals_to_rounds(dev, start[sel], dur[sel], n, rounds, delta)

    def sample_rounds_loop(self, rounds: int):
        """Seed per-device Python-loop sampler (reference / benchmark only)."""
        rng = np.random.default_rng(self.seed)
        delta = self.round_duration
        horizon = rounds * delta
        zeta = np.zeros((rounds, self.num_devices), np.int32)
        tau = np.zeros((rounds, self.num_devices), np.float64)
        for n in range(self.num_devices):
            p_contact = self.mean_contact / (self.mean_contact + self.mean_intercontact)
            t = 0.0
            in_contact = rng.random() < p_contact
            while t < horizon:
                if in_contact:
                    dur = max(rng.exponential(self.mean_contact), 1e-9)
                    end = t + dur
                    r0 = int(t / delta)
                    r1 = int(min(end, horizon - 1e-9) / delta)
                    for r in range(r0, min(r1 + 1, rounds)):
                        if zeta[r, n]:
                            continue
                        zeta[r, n] = 1
                        tau[r, n] = dur if r == r0 else end - r * delta
                    t = end
                else:
                    t += max(rng.exponential(self.mean_intercontact), 1e-9)
                in_contact = not in_contact
        return zeta, tau.astype(np.float32)


def contact_schedule(fl, rounds: int, seed: int | None = None):
    """Build (zeta, tau) from an FLConfig (speed-coupled if fl.speed > 0).

    Thin compatibility wrapper over the exponential model; new code should
    use ``repro.scenarios.ScenarioProvider``, which also derives h2.
    """
    seed = fl.seed if seed is None else seed
    if fl.speed > 0:
        proc = ContactProcess.from_speed(
            fl.num_devices, fl.speed, fl.contact_const, fl.intercontact_const,
            fl.round_duration, seed,
        )
    else:
        proc = ContactProcess(
            fl.num_devices, fl.mean_contact, fl.mean_intercontact,
            fl.round_duration, seed,
        )
    return proc.sample_rounds(rounds)
