"""Exponential contact / inter-contact process (paper §III-B).

Each device alternates contact periods tau ~ Exp(mean c_n) and
inter-contact gaps t ~ Exp(mean lambda_n).  Rounds have duration delta;
zeta_n^(r) = 1 in the round where a contact event begins (one upload
opportunity per contact, with the full sampled contact duration tau
available for the transfer) — matching the paper's abstraction where
tau_n^(r) bounds the upload bits via tau * A.

With speed coupling (Lemma/Corollary setting): c = C / v, lambda = L / v.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ContactProcess:
    num_devices: int
    mean_contact: float  # c_n
    mean_intercontact: float  # lambda_n
    round_duration: float  # delta
    seed: int = 0

    @classmethod
    def from_speed(cls, num_devices, speed, contact_const, intercontact_const,
                   round_duration, seed=0):
        v = max(speed, 1e-6)
        return cls(
            num_devices,
            mean_contact=contact_const / v,
            mean_intercontact=intercontact_const / v,
            round_duration=round_duration,
            seed=seed,
        )

    def sample_rounds(self, rounds: int):
        """Returns (zeta, tau): each (rounds, num_devices).

        Per Algorithm 1's zeta_n^(r): a device is "in contact in round r" for
        EVERY round its contact period overlaps.  tau[r, n] is the upload
        window available in that round: the full sampled contact duration in
        the round where the contact begins (the paper's tau ~ Exp(c)), and
        the remaining duration from the round boundary for continuation
        rounds of a long contact.
        """
        rng = np.random.default_rng(self.seed)
        delta = self.round_duration
        horizon = rounds * delta
        zeta = np.zeros((rounds, self.num_devices), np.int32)
        tau = np.zeros((rounds, self.num_devices), np.float64)
        for n in range(self.num_devices):
            # start either in contact or in a gap, per renewal stationarity
            p_contact = self.mean_contact / (self.mean_contact + self.mean_intercontact)
            t = 0.0
            in_contact = rng.random() < p_contact
            while t < horizon:
                if in_contact:
                    dur = max(rng.exponential(self.mean_contact), 1e-9)
                    end = t + dur
                    r0 = int(t / delta)
                    r1 = int(min(end, horizon - 1e-9) / delta)
                    for r in range(r0, min(r1 + 1, rounds)):
                        if zeta[r, n]:
                            continue
                        zeta[r, n] = 1
                        tau[r, n] = dur if r == r0 else end - r * delta
                    t = end
                else:
                    t += max(rng.exponential(self.mean_intercontact), 1e-9)
                in_contact = not in_contact
        return zeta, tau.astype(np.float32)


def contact_schedule(fl, rounds: int, seed: int | None = None):
    """Build (zeta, tau) from an FLConfig (speed-coupled if fl.speed > 0)."""
    seed = fl.seed if seed is None else seed
    if fl.speed > 0:
        proc = ContactProcess.from_speed(
            fl.num_devices, fl.speed, fl.contact_const, fl.intercontact_const,
            fl.round_duration, seed,
        )
    else:
        proc = ContactProcess(
            fl.num_devices, fl.mean_contact, fl.mean_intercontact,
            fl.round_duration, seed,
        )
    return proc.sample_rounds(rounds)
