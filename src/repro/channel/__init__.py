from repro.channel.wireless import (
    WirelessChannel,
    energy_joules,
    shannon_rate,
)

__all__ = ["WirelessChannel", "shannon_rate", "energy_joules"]
