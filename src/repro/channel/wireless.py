"""TR 38.901 UMi-Street-Canyon wireless channel (paper §VI, Table I).

PL_LOS  = 32.4 + 21.0 log10(d) + 20 log10(f_GHz)   [dB]
PL_NLOS = 32.4 + 31.9 log10(d) + 20 log10(f_GHz)   [dB]
Shadowing: lognormal, sigma = 4 dB (LOS) / 8.2 dB (NLOS).
LOS probability (UMi): P = 1 for d <= 18 m, else 18/d + exp(-d/36)(1-18/d).

Channel gain |h|^2 = 10^(-PL_total/10); rate = B log2(1 + p|h|^2/(B N0));
energy for a payload = p * bits / rate.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def shannon_rate(p, h2, bandwidth: float, noise_dbm_hz: float = -174.0):
    """bits/s for transmit power p (W) and channel gain |h|^2."""
    n0 = 10 ** (noise_dbm_hz / 10.0) / 1000.0  # W/Hz
    return bandwidth * np.log2(1.0 + p * h2 / (bandwidth * n0))


def energy_joules(p, bits, rate):
    rate = np.maximum(rate, 1e-9)
    return p * bits / rate


@dataclasses.dataclass
class WirelessChannel:
    bandwidth: float = 1e6
    carrier_ghz: float = 3.5
    noise_dbm_hz: float = -174.0
    shadow_los_db: float = 4.0
    shadow_nlos_db: float = 8.2
    min_dist: float = 10.0
    max_dist: float = 100.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def noise_w_hz(self) -> float:
        return 10 ** (self.noise_dbm_hz / 10.0) / 1000.0

    def los_prob(self, d):
        d = np.asarray(d, np.float64)
        p = 18.0 / np.maximum(d, 1e-9) + np.exp(-d / 36.0) * (1 - 18.0 / np.maximum(d, 1e-9))
        return np.where(d <= 18.0, 1.0, np.minimum(p, 1.0))

    def pathloss_db(self, d, los):
        d = np.maximum(np.asarray(d, np.float64), 1.0)
        pl_los = 32.4 + 21.0 * np.log10(d) + 20.0 * np.log10(self.carrier_ghz)
        pl_nlos = 32.4 + 31.9 * np.log10(d) + 20.0 * np.log10(self.carrier_ghz)
        return np.where(los, pl_los, pl_nlos)

    def sample_gain(self, size) -> np.ndarray:
        """Sample |h|^2 for devices uniformly placed within comm range."""
        d = self._rng.uniform(self.min_dist, self.max_dist, size)
        los = self._rng.random(size) < self.los_prob(d)
        pl = self.pathloss_db(d, los)
        sigma = np.where(los, self.shadow_los_db, self.shadow_nlos_db)
        shadow = self._rng.normal(0.0, sigma)
        return 10 ** (-(pl + shadow) / 10.0)

    def rate(self, p, h2):
        return shannon_rate(p, h2, self.bandwidth, self.noise_dbm_hz)

    def mean_rate(self, p: float, samples: int = 4096) -> float:
        """Monte-Carlo average rate at power p (used as A_n in Lemmas 2-3)."""
        h2 = self.sample_gain(samples)
        return float(np.mean(self.rate(p, h2)))
