"""LaneGCN-lite on Argoverse — the paper's trajectory-prediction model (§VI-C).

ActorNet (1D conv + FPN-style fusion) + MapNet (graph conv over lane nodes) +
FusionNet (actor<->map attention) + regression head predicting 30 future
positions (3 s @ 10 Hz). ``d_model`` is the feature width (128 full size).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="lanegcn-argoverse",
        family="trajectory",
        num_layers=4,  # conv stages / gcn hops
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=0,
        dtype="float32",
        param_dtype="float32",
        source="paper §VI-C / Liang et al. ECCV20",
    )
)
