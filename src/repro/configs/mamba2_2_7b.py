"""Mamba2-2.7B — SSD (state-space duality) [arXiv:2405.21060].

SSM (attention-free): 64L, d_model=2560, vocab=50280, ssm_state=128.
expand=2 -> d_inner=5120, head_dim=64 -> 80 SSD value heads.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_heads=80,  # d_inner / 64
        ssm_chunk=256,
        conv_kernel=4,
        norm_eps=1e-5,
        source="arXiv:2405.21060",
    )
)
