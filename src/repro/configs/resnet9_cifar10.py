"""ResNet-9 on CIFAR-10 — the paper's own image-classification model (§VI).

Nine conv layers + BN + ReLU, two residual blocks, global pooling, FC head;
6,568,650 parameters at full width. ``d_model`` doubles as the base channel
width (64 at full size).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="resnet9-cifar10",
        family="vision",
        num_layers=9,
        d_model=64,  # base width
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=10,  # classes
        dtype="float32",
        param_dtype="float32",
        source="paper §VI / He et al. CVPR16",
    )
)
