"""Qwen3-32B [hf:Qwen/Qwen3-8B family card, 32B variant].

Dense: 64L, d_model=5120, 64 heads (GQA kv=8), d_ff=25600, vocab=151936.
qk_norm (per-head RMSNorm on q/k) — Qwen3 signature; no QKV bias.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
        source="hf:Qwen/Qwen3-8B",
    )
)
