"""Qwen2-VL-72B language backbone [arXiv:2409.12191].

VLM: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568, vocab=152064.
M-RoPE (temporal/height/width sections over the head dim); the vision
encoder (ViT + merger) is a STUB — ``input_specs`` feeds precomputed patch
embeddings, per the assignment carve-out.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
        mrope_sections=(16, 24, 24),  # t/h/w per Qwen2-VL (sums to head_dim/2)
        source="arXiv:2409.12191",
    )
)
