"""Zamba2-7B [arXiv:2411.15242].

Hybrid: 81-layer Mamba2 backbone with a SHARED attention block applied
every 6 layers. d_model=3584, 32 heads (kv=32) in the shared block,
d_ff=14336, vocab=32000, ssm_state=64.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_heads=112,  # d_inner=7168 / 64
        ssm_chunk=256,
        conv_kernel=4,
        attn_every=6,
        norm_eps=1e-5,
        source="arXiv:2411.15242",
    )
)
