from repro.configs.base import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    FLConfig,
    InputShape,
    ModelConfig,
    get_config,
    list_configs,
    load_all,
    register,
)

__all__ = [
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "FLConfig",
    "InputShape",
    "ModelConfig",
    "get_config",
    "list_configs",
    "load_all",
    "register",
]
