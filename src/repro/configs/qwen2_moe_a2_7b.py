"""Qwen2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

MoE: 24L, d_model=2048, 16 heads (GQA kv=16), vocab=151936,
60 routed experts top-4 + 4 shared experts, expert d_ff=1408.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        moe_d_ff=1408,
        vocab_size=151936,
        qkv_bias=True,
        num_experts=60,
        num_experts_per_tok=4,
        num_shared_experts=4,
        rope_theta=1e6,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )
)
