"""Qwen3-MoE-30B-A3B [hf:Qwen/Qwen3-30B-A3B].

MoE: 48L, d_model=2048, 32 heads (GQA kv=4), vocab=151936,
128 routed experts top-8 (no shared experts), expert d_ff=768, qk_norm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        moe_d_ff=768,
        vocab_size=151936,
        qk_norm=True,
        num_experts=128,
        num_experts_per_tok=8,
        num_shared_experts=0,
        rope_theta=1e6,
        source="hf:Qwen/Qwen3-30B-A3B",
    )
)
