"""Config system: model/arch configs, input shapes, and the arch registry.

Every assigned architecture registers a ``ModelConfig`` here (one module per
arch under ``repro/configs/``).  Configs are plain frozen dataclasses so they
are hashable (usable as jit static args) and printable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. Covers dense / MoE / SSM / hybrid / enc-dec / VLM."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | vision | trajectory
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Tuple[int, ...] = ()  # Qwen2-VL M-RoPE (t, h, w) splits
    sliding_window: int = 0  # 0 = full attention; >0 enables SW variant
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden size (d_ff used for shared/dense)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    # --- SSM / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0  # mamba2 value heads; 0 -> derived
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_kernel: int = 4
    attn_every: int = 0  # hybrid: shared attention block every k layers
    # --- enc-dec (whisper) ----------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0  # frames after the (stubbed) conv frontend
    # --- misc -----------------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "none"  # none | full | dots  (activation checkpoint policy)
    kv_cache_dtype: str = ""  # "" = activation dtype; "int8" = quantized cache
    expert_dtype: str = ""  # "" = param dtype; "int8" = quantized expert weights
    source: str = ""  # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if long_500k decode is runnable (sub-quadratic path exists)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "audio":
            return False  # enc-dec: skipped, see DESIGN.md §4
        return True  # dense/moe/vlm use the sliding-window decode variant

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def reduced(self) -> "ModelConfig":
        """Reduced variant for CPU smoke tests (2 layers, d_model<=512, <=4 experts)."""
        changes = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=32,
            ssm_heads=0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )
        if self.is_moe:
            changes.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                moe_d_ff=min(self.moe_d_ff or self.d_ff, 128),
            )
        if self.encoder_layers:
            changes.update(encoder_layers=2, encoder_seq=64)
        if self.attn_every:
            changes.update(attn_every=2, num_layers=4)
        return dataclasses.replace(self, **changes)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Federated / training config (the paper's system knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FLConfig:
    """Paper system model parameters (Table I defaults)."""

    num_devices: int = 20  # N
    rounds: int = 200  # R
    round_duration: float = 10.0  # delta, seconds
    learning_rate: float = 0.01  # eta
    batch_size: int = 32
    # mobility (exponential inter-contact model, §III-B)
    mean_contact: float = 4.0  # c_n seconds
    mean_intercontact: float = 400.0  # lambda_n seconds
    speed: float = 0.0  # if >0: c=C/v, lambda=Lambda/v
    contact_const: float = 40.0  # C
    intercontact_const: float = 4000.0  # Lambda
    # scenario engine (repro/scenarios): trace-based mobility + channels
    mobility_model: str = "exponential"  # exponential|rwp|gauss_markov|manhattan|hotspot|static
    area: float = 1000.0  # m, square side
    comm_range: float = 100.0  # m, device-MES contact range
    mobility_dt: float = 1.0  # s, kinematics sampling step
    pause_max: float = 5.0  # s, rwp waypoint pause
    gm_corr_dist: float = 200.0  # m, gauss_markov velocity decorrelation
    street_block: float = 100.0  # m, manhattan grid spacing
    num_hotspots: int = 4
    hotspot_radius: float = 150.0  # m, RMS excursion around a hotspot
    shadow_corr_dist: float = 25.0  # m, Gudmundson shadowing decorrelation
    # scenario backend: "numpy" keeps the oracle kinematics; "jax" builds
    # the whole schedule device-resident (repro/scenarios/jax_kinematics).
    # Host-side knob — the compiled round consumes the same arrays either way
    scenario_backend: str = "numpy"
    # per-client system heterogeneity (repro/scenarios/heterogeneity):
    # contact windows are gated by a Markov availability chain, an Exp
    # compute-latency draw, and an i.i.d. dropout coin.  Defaults disable
    # the layer entirely (no schedule rewrite, no aux masks)
    het_availability: float = 1.0  # stationary P(client available)
    het_avail_persist: float = 0.0  # availability chain persistence rho
    het_compute_mean: float = 0.0  # s, mean Exp local-compute latency
    het_dropout: float = 0.0  # P(upload lost despite a fitting window)
    # wireless (Table I)
    bandwidth: float = 1e6  # B_n, Hz
    carrier_ghz: float = 3.5
    max_power: float = 0.2  # W
    noise_dbm_hz: float = -174.0
    value_bits: int = 32  # u
    # energy / MADS
    energy_budget: Tuple[float, float] = (50.0, 150.0)  # J, uniform range
    lyapunov_v: float = 1e-4
    # sparsification
    sparsifier: str = "exact"  # exact | sampled
    sample_size: int = 65536
    # compression codecs (repro/compression; host-side — consumed by the
    # baselines.* policy factories, not by the compiled round)
    compress_b_min: int = 2  # smallest usable value bit-width
    compress_b_max: int = 16  # largest value bit-width the codecs consider
    fixed_k_frac: float = 0.01  # fixed-kb baseline: keep-fraction target
    fixed_bits: int = 8  # fixed-kb baseline: value bit-width
    # joint codec: solve (k_l, b_l) per pytree leaf by greedy water-filling
    # against the same tau*A budget (repro/compression/perlayer.py)
    per_layer_budget: bool = False
    # staleness-discounted aggregation (core/afl.py::StalenessWeight): the
    # FedAsync alpha * s(delta_tau) mixing family shared by the engines and
    # the streaming ingestion server (repro/serve).  The default — constant
    # at alpha = 1 — is the paper's rule and compiles to the identity
    staleness_family: str = "constant"  # constant | hinge | poly
    staleness_alpha: float = 1.0
    staleness_hinge_a: float = 10.0
    staleness_hinge_b: float = 4.0
    staleness_poly_a: float = 0.5
    # telemetry (repro/telemetry): True enables the built-in AFL metric
    # registry (staleness/bits/tau/k/b histograms + round counters) in the
    # runners; consumed host-side when resolving the registry, the compiled
    # round never reads it
    telemetry: bool = False
    # per-device flight recorder / online theory probes: either knob makes
    # the runners carry a TelemetrySuite (global registry + the requested
    # extras) instead of the bare registry — also host-side only
    telemetry_perdevice: bool = False
    telemetry_probes: bool = False
    # non-iid
    dirichlet_rho: float = 0.5
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


ASSIGNED_ARCHS = (
    "qwen2-vl-72b",
    "llama3.2-3b",
    "internlm2-1.8b",
    "qwen2-7b",
    "qwen3-32b",
    "mamba2-2.7b",
    "whisper-large-v3",
    "qwen2-moe-a2.7b",
    "zamba2-7b",
    "qwen3-moe-30b-a3b",
)


def load_all() -> None:
    """Import every config module (they self-register)."""
    import importlib

    for mod in (
        "qwen2_vl_72b",
        "llama3_2_3b",
        "internlm2_1_8b",
        "qwen2_7b",
        "qwen3_32b",
        "mamba2_2_7b",
        "whisper_large_v3",
        "qwen2_moe_a2_7b",
        "zamba2_7b",
        "qwen3_moe_30b_a3b",
        "resnet9_cifar10",
        "lanegcn_argoverse",
    ):
        importlib.import_module(f"repro.configs.{mod}")
