"""Whisper-large-v3 [arXiv:2212.04356].

Audio encoder-decoder: 32L decoder (+32L encoder), d_model=1280,
20 heads (kv=20, i.e. MHA), d_ff=5120, vocab=51866.
The mel-spectrogram + conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (1500 frames), per the assignment carve-out.
long_500k is SKIPPED for this arch (enc-dec full-attention decoder;
see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51866,
        qkv_bias=True,
        encoder_layers=32,
        encoder_seq=1500,
        norm_eps=1e-5,
        source="arXiv:2212.04356",
    )
)
