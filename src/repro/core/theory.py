"""Closed-form theory from the paper (Lemmas 2-4, Theorems 1-2, Corollary 1).

These are the quantities the experiments validate against:
* ``staleness_second_moment`` — Lemma 2's Theta_n bound,
* ``gamma`` — Lemma 3's sparsification-survival factor,
* ``theorem1_rhs`` / ``theorem2_rhs`` — the convergence bounds,
* ``corollary1_bound(v)`` — the U-shaped speed curve (Remark 3).

Everything is plain numpy so benchmarks can sweep parameters cheaply.
"""
from __future__ import annotations

import numpy as np


def staleness_second_moment(c: float, lam: float, delta: float) -> float:
    """Lemma 2: E[(theta_n)^2] <= Theta_n.

    Theta = 1 + lam/(lam+c) * (e^{-4d/l} - 3 e^{-3d/l} + 4 e^{-2d/l})
                              / (1 - 2 e^{-d/l} + e^{-2d/l}).
    """
    x = np.exp(-delta / lam)
    num = x**4 - 3 * x**3 + 4 * x**2
    den = max((1 - x) ** 2, 1e-12)
    return 1.0 + (lam / (lam + c)) * num / den


def gamma(rate: float, c: float, s: int, u: int = 32) -> float:
    """Lemma 3 as written: gamma_n = exp(-(u + log2 s) / (A_n c_n)).

    NOTE (EXPERIMENTS.md §Paper-validation): with realistic rates this is
    ~1 - 1e-5 and the resulting (1-gamma)||x||^2 UNDER-estimates the true
    sparsification error whenever the contact window cannot carry the whole
    model — the appendix's final inequality is loose in the wrong direction
    for gamma -> 1.  Use ``gamma_model`` for quantitative work.
    """
    return float(np.exp(-(u + np.log2(max(s, 2))) / max(rate * c, 1e-12)))


def gamma_model(rate: float, c: float, s: int, u: int = 32) -> float:
    """Full-model form: probability the window carries ALL s coordinates,
    gamma_model = exp(-s (u + log2 s)/(A c)).  This is the variant that
    reproduces the paper's U-shaped speed curve at vehicular speeds."""
    bits = s * (u + np.log2(max(s, 2)))
    return float(np.exp(-min(bits / max(rate * c, 1e-12), 700.0)))


def expected_error_fraction(rate: float, c: float, s: int, u: int = 32,
                            mc: int = 20000, seed: int = 0) -> float:
    """Monte-Carlo E[(s - k)/s] with k = min(tau A/(u+log2 s), s), the
    *correct* expected top-k residual-mass upper bound for uniform x."""
    rng = np.random.default_rng(seed)
    tau = rng.exponential(c, mc)
    k = np.minimum(tau * rate / (u + np.log2(max(s, 2))), s)
    return float(np.mean((s - k) / s))


def sparsification_error_factor(rate: float, c: float, s: int, u: int = 32) -> float:
    """Lemma 3 bound: E||x - S(x)||^2 <= (1 - gamma) ||x||^2."""
    return 1.0 - gamma(rate, c, s, u)


def local_memory_bound(rate, c, lam, delta, s, eta, g2, u: int = 32) -> float:
    """Lemma 4: E||e_n||^2 <= 4 (1 - gamma^2)/gamma^2 * Theta * eta^2 G^2."""
    gam = gamma(rate, c, s, u)
    th = staleness_second_moment(c, lam, delta)
    return 4 * (1 - gam**2) / max(gam**2, 1e-12) * th * eta**2 * g2


def theorem1_rhs(
    f0_gap: float,
    eta: float,
    big_l: float,
    g2: float,
    sigma: float,
    n: int,
    rounds: int,
    zeta: np.ndarray,  # (R, N)
    theta: np.ndarray,  # (R, N)
    k: np.ndarray,  # (R, N)
    x_norm2: np.ndarray,  # (R, N)
    s: int,
) -> float:
    """Theorem 1 upper bound on E||grad F(z^R)||^2 (round-wise, empirical)."""
    t1 = 4 * f0_gap / (eta * rounds)
    coupling = zeta * theta * (5 - 3 * k / s) * x_norm2
    t2 = 4 * big_l**2 / (n * rounds) * coupling.sum()
    t3 = 8 * eta**2 * big_l**2 * g2 / (n * rounds) * (theta**2).sum()
    t4 = 4 * eta * big_l * sigma / n
    return float(t1 + t2 + t3 + t4)


def theorem2_rhs(
    f0_gap: float,
    big_l: float,
    sigma: float,
    g2: float,
    n: int,
    rounds: int,
    rate: float,
    c: float,
    lam: float,
    delta: float,
    s: int,
    u: int = 32,
) -> float:
    """Theorem 2 bound (expectation over contact statistics)."""
    gam = max(gamma(rate, c, s, u), 1e-9)
    th = staleness_second_moment(c, lam, delta)
    t1 = 8 * big_l * f0_gap / np.sqrt(rounds)
    t2 = 2 * sigma / (n * np.sqrt(rounds))
    poly = 16 - 8 * gam - 11 * gam**2 + 6 * gam**3
    t3 = g2 / (n * rounds) * n * poly * th / gam**2  # summed over N identical devices
    return float(t1 + t2 + t3)


def corollary1_bound(
    v: float,
    f0_gap: float,
    big_l: float,
    sigma: float,
    g2: float,
    n: int,
    rounds: int,
    rate: float,
    contact_const: float,
    intercontact_const: float,
    delta: float,
    s: int,
    u: int = 32,
    gamma_mode: str = "paper",
) -> float:
    """Corollary 1: bound as a function of device speed v (c=C/v, lam=L/v).

    gamma_mode="paper" uses the literal per-element exponent (which only
    turns upward at ~1e5 m/s with Table-I constants); "model" uses the
    full-model bit cost s(u+log2 s) (see ``gamma_model``), which reproduces
    the paper's Fig. 5 U-shape at vehicular speeds.
    """
    big_c, big_l_mob = contact_const, intercontact_const
    t1 = 8 * big_l * f0_gap / np.sqrt(rounds)
    t2 = 2 * sigma / (n * np.sqrt(rounds))
    bit_cost = (u + np.log2(max(s, 2))) * (s if gamma_mode == "model" else 1.0)
    expo = np.exp(min(2 * bit_cost * v / (rate * big_c), 700.0))
    y = np.exp(-delta * v / big_l_mob)
    num = y**4 - 3 * y**3 + 4 * y**2
    den = max((1 - y) ** 2, 1e-12)
    theta_term = 1 + (big_l_mob / (big_l_mob + big_c)) * num / den
    t3 = 16 * g2 * expo / rounds * theta_term
    return float(t1 + t2 + t3)


def optimal_speed(args: dict, v_grid=None) -> float:
    """argmin_v of Corollary 1 on a grid (Remark 3's interior optimum)."""
    v_grid = v_grid if v_grid is not None else np.linspace(0.5, 60.0, 240)
    vals = [corollary1_bound(v, **args) for v in v_grid]
    return float(v_grid[int(np.argmin(vals))])
