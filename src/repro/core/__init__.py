"""The paper's primary contribution: AFL + mobility-aware dynamic
sparsification (MADS), as a composable JAX module.

Submodules:
  sparsify     top-k sparsification + error feedback (§III-D)
  afl          Algorithm 1 — the AFL training process (simulation mode)
  mads         Algorithm 2 — Lyapunov-controlled k/p (Propositions 1-2)
  theory       Lemmas 2-4 / Theorems 1-2 / Corollary 1 closed forms
  baselines    SFL-Spar, FedAsync, AFL-Spar, FedMobile, Optimal (§VI-B)
               + compression-codec policies (mads-joint, qsgd, fixed-kb)
  distributed  pjit AFL train step for the assigned architectures

See README.md in this directory for the paper-symbol -> code table and
how the repro/compression subsystem plugs into the round.
"""
from repro.core.sparsify import (
    bits_for_k,
    k_for_bits,
    sparsify_topk,
    sparsify_tree,
    threshold_for_k,
)

__all__ = [
    "bits_for_k",
    "k_for_bits",
    "sparsify_topk",
    "sparsify_tree",
    "threshold_for_k",
]
