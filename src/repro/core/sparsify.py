"""Top-k gradient sparsification with error feedback (paper §III-D).

TPU adaptation (DESIGN.md §3): MADS computes the sparsification degree k
per round from contact time, so k is a *traced* value — ``jax.lax.top_k``
(static k) cannot be used.  We instead implement S(x) as magnitude
thresholding at the (1 - k/s) quantile of |x|:

* ``exact``  — threshold from a full descending sort (small models /
  simulation mode; bit-exact top-k semantics up to ties);
* ``sampled`` — threshold estimated from a strided sample of m elements
  (distributed mode; O(m log m), k hit within sampling error).

Both keep shapes static: the "upload" is ``x * mask`` and the error memory
update is ``x * (1 - mask)`` — the fused form of these two passes is the
``sparsify_ef`` Pallas kernel.  Bit accounting uses the realised mask
population count: bits = k_actual * (u + log2 s)  (paper eq. 7c).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def bits_for_k(k, s: int, u: int = 32):
    """Upload payload in bits for k selected of s parameters (paper §III-D)."""
    return k * (u + jnp.ceil(jnp.log2(jnp.asarray(s, jnp.float32))))


def k_for_bits(bits, s: int, u: int = 32):
    """Largest k transmittable within ``bits`` (Proposition 1 with bits=tau*A)."""
    k = bits / (u + jnp.ceil(jnp.log2(jnp.asarray(s, jnp.float32))))
    return jnp.clip(k, 0.0, float(s))


def threshold_for_k(x_abs: jax.Array, k, *, method: str = "exact", sample: int = 65536):
    """|x| threshold such that ~k elements exceed it. k may be traced (float)."""
    s = x_abs.size
    k = jnp.clip(jnp.asarray(k, jnp.float32), 0.0, float(s))
    if method == "exact":
        srt = jnp.sort(x_abs.reshape(-1))[::-1]  # descending
        idx = jnp.clip(jnp.floor(k).astype(jnp.int32) - 1, 0, s - 1)
        t = srt[idx]
        # k == 0 -> nothing passes
        return jnp.where(k < 1.0, jnp.inf, t)
    if method == "sampled":
        m = min(sample, s)
        stride = max(s // m, 1)
        sub = jax.lax.slice(x_abs.reshape(-1), (0,), (m * stride,), (stride,))
        srt = jnp.sort(sub)[::-1]
        frac = k / float(s)
        idx = jnp.clip(jnp.floor(frac * m).astype(jnp.int32) - 1, 0, m - 1)
        t = srt[idx]
        return jnp.where(k < 1.0, jnp.inf, t)
    raise ValueError(f"unknown method {method!r}")


def sparsify_topk(x: jax.Array, k, *, method: str = "exact", sample: int = 65536):
    """S(x): keep the ~k largest-magnitude entries.

    Returns (upload, error, k_actual): upload = S(x), error = x - S(x),
    k_actual = realised number of selected entries (for bit accounting).
    """
    x_abs = jnp.abs(x.astype(jnp.float32))
    t = threshold_for_k(x_abs, k, method=method, sample=sample)
    if jax.default_backend() == "tpu" and x.ndim == 1:
        # fused single-pass kernel (repro/kernels/sparsify_ef.py)
        from repro.kernels.sparsify_ef import sparsify_ef as _kernel

        return _kernel(x, t, interpret=False)
    mask = x_abs >= t
    upload = jnp.where(mask, x, jnp.zeros_like(x))
    error = jnp.where(mask, jnp.zeros_like(x), x)
    return upload, error, jnp.sum(mask).astype(jnp.float32)


def quantize_values(x, bits: int):
    """Symmetric uniform quantisation of the upload VALUES to ``bits`` bits
    (the paper's u; §III-D assumes u=32 floats — transmitting u<32 is a
    beyond-paper extension where Proposition 1 buys k* ~ (32+log2 s)/(u+log2 s)
    more coordinates per contact window and the error-feedback memory
    absorbs the quantisation residual).

    x may be a pytree; returns the dequantised-on-arrival tensor(s) (what
    the MES reconstructs).  bits >= 32 is a no-op.
    """
    if bits >= 32:
        return x

    def q(leaf):
        lf = leaf.astype(jnp.float32)
        amax = jnp.max(jnp.abs(lf))
        levels = float(2 ** (bits - 1) - 1)
        scale = jnp.maximum(amax, 1e-12) / levels
        return (jnp.round(lf / scale) * scale).astype(leaf.dtype)

    return jax.tree.map(q, x)


def _strided_sample(leaf, m: int):
    """~m-element magnitude sample via a rectangular strided slice.

    CRITICAL for the distributed path: flattening a sharded tensor
    (``reshape(-1)``) forces GSPMD to ALL-GATHER it (measured: 3x 16.6 GB f32
    gathers per AFL round on qwen2-moe — §Perf B-series).  A strided
    ``lax.slice`` keeps the shards local and only the tiny sample block is
    ever replicated.  Leading dims are strided first so the (usually sharded)
    trailing dim stays contiguous.
    """
    shape = leaf.shape
    size = leaf.size
    if size <= m or not shape:
        return jnp.abs(leaf.astype(jnp.float32)).reshape(-1)
    strides = [1] * len(shape)
    red = size / m
    order = sorted(range(len(shape)), key=lambda i: (i == len(shape) - 1, -shape[i]))
    for i in order:
        if red <= 1.0:
            break
        st = int(min(shape[i], max(1, round(red))))
        strides[i] = st
        red /= st
    block = jax.lax.slice(leaf, (0,) * len(shape), shape, tuple(strides))
    return jnp.abs(block.astype(jnp.float32)).reshape(-1)


def tree_threshold(tree, k, *, method: str = "exact", sample: int = 65536):
    """GLOBAL |x| threshold across all leaves such that ~k elements pass
    (the paper treats x_n as one flat vector).  k may be traced."""
    leaves = jax.tree.leaves(tree)
    sizes = [l.size for l in leaves]
    s = sum(sizes)
    if method == "exact":
        flat = jnp.concatenate([jnp.abs(l.astype(jnp.float32)).reshape(-1) for l in leaves])
        return threshold_for_k(flat, k, method="exact")
    m_per = [max(int(sample * sz / s), 16) for sz in sizes]
    flat = jnp.concatenate(
        [_strided_sample(l, m) for l, m in zip(leaves, m_per)]
    )
    frac = jnp.clip(jnp.asarray(k, jnp.float32) / float(s), 0.0, 1.0)
    srt = jnp.sort(flat)[::-1]
    idx = jnp.clip(jnp.floor(frac * flat.size).astype(jnp.int32) - 1, 0, flat.size - 1)
    return jnp.where(jnp.asarray(k, jnp.float32) < 1.0, jnp.inf, srt[idx])


def sparsify_tree(tree, k, *, method: str = "exact", sample: int = 65536):
    """Tree-level S(x): one global magnitude threshold across all leaves
    (see ``tree_threshold``)."""
    leaves, treedef = jax.tree.flatten(tree)
    t = tree_threshold(tree, k, method=method, sample=sample)
    ups, errs, ks = [], [], []
    for l in leaves:
        mask = jnp.abs(l.astype(jnp.float32)) >= t
        ups.append(jnp.where(mask, l, jnp.zeros_like(l)))
        errs.append(jnp.where(mask, jnp.zeros_like(l), l))
        ks.append(jnp.sum(mask).astype(jnp.float32))
    return (
        jax.tree.unflatten(treedef, ups),
        jax.tree.unflatten(treedef, errs),
        sum(ks),
    )
