"""MADS — mobility-aware dynamic sparsification (paper §V, Algorithm 2).

Per contact, each device solves P3 in closed form:

* Proposition 1: the contact-time constraint is tight,
      k* = tau * A(p*) / (u + log2 s).
* Proposition 2: KKT water-filling power
      p* = clip( 3 V zeta theta B ||x||^2 / (q s (u + log2 s))  -  B N0/|h|^2,
                 0, P ),
      P = min(p_max, (B N0/|h|^2) (2^{s (u+log2 s)/(tau B)} - 1)),
  where the upper branch of P caps k at s (no point transmitting more than
  every coordinate).
* Virtual energy queue (eq. 8): q <- max(q + E - E_con/R, 0), E = p * tau
  (payload always fills the contact window under Proposition 1).

All functions are jnp-traceable so the controller runs inside the jitted
AFL round (vmapped over devices).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def log2s(s: int, u: int) -> float:
    import numpy as np

    return float(u + np.ceil(np.log2(max(s, 2))))


def rate_bps(p, h2, bandwidth, n0):
    return bandwidth * jnp.log2(1.0 + p * h2 / (bandwidth * n0))


def power_cap(tau, h2, s: int, u: int, bandwidth, n0, p_max):
    """P_n^(r) in Proposition 2: cap from (14b) k<=s, and p_max."""
    exponent = float(s) * log2s(s, u) / (jnp.maximum(tau, 1e-9) * bandwidth)
    exponent = jnp.minimum(exponent, 60.0)  # avoid inf for tiny tau
    p_k_cap = bandwidth * n0 / jnp.maximum(h2, 1e-30) * (2.0**exponent - 1.0)
    return jnp.minimum(p_max, p_k_cap)


def mads_power(v_weight, zeta, theta, x_norm2, q, tau, h2, s: int, u: int,
               bandwidth, n0, p_max):
    """Proposition 2 closed form."""
    cap = power_cap(tau, h2, s, u, bandwidth, n0, p_max)
    num = 3.0 * v_weight * zeta * theta * bandwidth * x_norm2
    den = jnp.maximum(q, 1e-12) * float(s) * log2s(s, u)
    p = num / den - bandwidth * n0 / jnp.maximum(h2, 1e-30)
    return jnp.clip(p, 0.0, cap)


def mads_k(p, tau, h2, s: int, u: int, bandwidth, n0):
    """Proposition 1: k* = tau A / (u + log2 s), clipped to [0, s]."""
    a = rate_bps(p, h2, bandwidth, n0)
    return jnp.clip(tau * a / log2s(s, u), 0.0, float(s))


@dataclasses.dataclass(frozen=True)
class MadsController:
    """Per-round (k, p) selection + queue bookkeeping (Algorithm 2)."""

    s: int  # model size
    u: int = 32
    bandwidth: float = 1e6
    noise_w_hz: float = 10 ** (-174.0 / 10.0) / 1000.0
    p_max: float = 0.2
    v_weight: float = 1e-4
    energy_unconstrained: bool = False  # the "Optimal" benchmark

    def select(self, zeta, theta, x_norm2, q, tau, h2):
        """All inputs per-device arrays. Returns (k, p, energy)."""
        if self.energy_unconstrained:
            p = power_cap(tau, h2, self.s, self.u, self.bandwidth, self.noise_w_hz,
                          self.p_max)
        else:
            p = mads_power(
                self.v_weight, zeta, theta.astype(jnp.float32), x_norm2, q, tau, h2,
                self.s, self.u, self.bandwidth, self.noise_w_hz, self.p_max,
            )
        k = mads_k(p, tau, h2, self.s, self.u, self.bandwidth, self.noise_w_hz)
        k = k * zeta
        p = p * zeta
        energy = p * tau  # E = p * bits/A = p * tau under Proposition 1
        return k, p, energy

    def queue_update(self, q, energy, energy_budget, rounds: int):
        """Virtual queue evolution, eq. (8)."""
        return jnp.maximum(q + energy - energy_budget / rounds, 0.0)
