"""Simulation driver: wires data, mobility, channel, and the AFL engine.

This is the harness behind every paper-replication experiment (Figs. 2-11):
build a federation, pick a policy (MADS or a §VI-B baseline), run R rounds,
record metrics + periodic global-model evaluation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import WirelessChannel
from repro.core import baselines as BL
from repro.core.afl import afl_init, afl_round
from repro.scenarios import ScenarioProvider
from repro.utils import get_logger

log = get_logger("repro.runner")


@dataclasses.dataclass
class RunResult:
    policy: str
    history: dict  # lists per metric
    final_eval: float
    state: object


def evaluate(model, cfg, params, eval_batch) -> float:
    """Family-appropriate eval metric on the global model."""
    if cfg.family == "vision":
        from repro.models.resnet import accuracy

        return float(accuracy(params, cfg, eval_batch))
    if cfg.family == "trajectory":
        from repro.models.lanegcn import ade, forward

        pred, _ = forward(params, cfg, eval_batch["past"], eval_batch["lanes"])
        return float(ade(pred, eval_batch["future"]))
    return float(model.loss_fn(params, cfg, eval_batch))


def run_afl(
    model,
    cfg,
    fl,
    policy_name: str,
    loader,
    eval_batch,
    rounds: Optional[int] = None,
    eval_every: int = 20,
    seed: Optional[int] = None,
    schedule=None,
    log_progress: bool = False,
) -> RunResult:
    rounds = rounds or fl.rounds
    seed = fl.seed if seed is None else seed
    s = model.num_params()

    policy = BL.ALL[policy_name](s, fl)
    if schedule is None:
        provider = ScenarioProvider.from_config(fl, rounds, seed)
    elif isinstance(schedule, ScenarioProvider):
        provider = schedule  # caller-built scenario, reused as-is
    else:  # legacy (zeta, tau) [+ h2] arrays; without h2: i.i.d. gains
        chan = WirelessChannel(
            bandwidth=fl.bandwidth, carrier_ghz=fl.carrier_ghz,
            noise_dbm_hz=fl.noise_dbm_hz, seed=seed + 1,
        )
        provider = ScenarioProvider.from_arrays(*schedule, channel=chan)
    if policy_name == "fedmobile":
        zeta, tau, h2 = provider.schedule()
        zeta, tau = BL.apply_relays(zeta, tau, seed=seed)
        provider = ScenarioProvider.from_arrays(zeta, tau, h2=h2)

    rng_np = np.random.default_rng(seed + 2)
    budgets = jnp.asarray(
        rng_np.uniform(*fl.energy_budget, fl.num_devices), jnp.float32
    )

    state = afl_init(model, cfg, fl, jax.random.key(seed))
    eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
    hist: dict = {
        "round": [], "eval": [], "uploads": [], "k_mean": [], "energy": [],
        "theta_mean": [], "power_mean": [],
    }

    t0 = time.time()
    tot_uploads = tot_k = tot_power = 0.0
    for r in range(rounds):
        batch = {k: jnp.asarray(v) for k, v in loader.sample_all().items()}
        zeta_r, tau_r, h2_r = provider.round(r)
        state, m = afl_round(
            state, batch, jnp.asarray(zeta_r), jnp.asarray(tau_r),
            jnp.asarray(h2_r, jnp.float32), budgets,
            model=model, cfg=cfg, fl=fl, policy=policy,
        )
        tot_uploads += float(jnp.sum(m["success"]))
        tot_k += float(jnp.sum(m["k"]))
        tot_power += float(jnp.sum(m["power"]))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            ev = evaluate(model, cfg, state.w, eval_batch)
            hist["round"].append(r + 1)
            hist["eval"].append(ev)
            hist["uploads"].append(tot_uploads)  # cumulative
            hist["k_mean"].append(tot_k / max(tot_uploads, 1.0))
            hist["energy"].append(float(jnp.sum(state.energy)))
            hist["theta_mean"].append(float(jnp.mean(m["theta"])))
            hist["power_mean"].append(tot_power / max(tot_uploads, 1.0))
            if log_progress:
                log.info(
                    "policy=%s r=%d eval=%.4f uploads=%.0f k=%.0f E=%.0fJ",
                    policy_name, r + 1, ev, hist["uploads"][-1],
                    hist["k_mean"][-1], hist["energy"][-1],
                )
    return RunResult(policy_name, hist, hist["eval"][-1], state)
