"""Simulation driver: wires data, mobility, channel, and the AFL engine.

This is the harness behind every paper-replication experiment (Figs. 2-11):
build a federation, pick a policy (MADS or a §VI-B baseline), run R rounds,
record metrics + periodic global-model evaluation.

Two execution engines share this entry point:

* ``engine="loop"`` — the per-round Python loop below (one jitted
  ``afl_round`` dispatch per round; easy to instrument).
* ``engine="scan"`` — ``repro.experiments.scan_engine.run_afl_scanned``:
  the whole run lowered into one compiled ``lax.scan`` program
  (metric-equivalent; see tests/test_experiments.py).
"""
from __future__ import annotations

import dataclasses
from contextlib import nullcontext
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import WirelessChannel
from repro.core import baselines as BL
from repro.core.afl import afl_init, afl_round
from repro.scenarios import ScenarioProvider
from repro.telemetry import AFL_REGISTRY, HIST_KEYS, jit_record, record_het
from repro.utils import get_logger

log = get_logger("repro.runner")

__all__ = ["HIST_KEYS", "RunResult", "run_afl"]  # HIST_KEYS re-exported
# from repro.telemetry.metrics — the single source of truth for both engines


@dataclasses.dataclass
class RunResult:
    policy: str
    history: dict  # lists per metric
    final_eval: float
    state: object
    # fetched MetricRegistry snapshot, or a TelemetrySuite's sectioned
    # {"metrics"/"device"/"probes"} snapshot when the suite knobs are on
    telemetry: Optional[dict] = None


def resolve_telemetry(fl, telemetry, s: int = 0):
    """The run's telemetry: an explicit registry/suite wins; otherwise the
    FLConfig knobs decide — ``telemetry`` alone turns on the built-in AFL
    registry, and ``telemetry_perdevice`` / ``telemetry_probes`` upgrade
    it to a ``TelemetrySuite`` carrying the per-device flight recorder
    and/or the theory probes alongside the registry.

    ``s`` is the model size the engines pass (``model.num_params()``) —
    the probes compare measured error/staleness/success against the
    closed forms at that (s, u) operating point.  Resolution runs on the
    FULL FLConfig, before ``experiments.grid.engine_fl`` projects it for
    the jit caches, so the knobs never trigger recompiles.
    """
    if telemetry is not None:
        return telemetry
    want_dev = getattr(fl, "telemetry_perdevice", False)
    want_probes = getattr(fl, "telemetry_probes", False) and s > 0
    if want_dev or want_probes:
        from repro.telemetry import DeviceTable, TelemetrySuite, TheoryProbes

        return TelemetrySuite(
            metrics=AFL_REGISTRY,
            device=DeviceTable(fl.num_devices) if want_dev else None,
            probes=(TheoryProbes(s=s, u=fl.value_bits)
                    if want_probes else None),
        )
    return AFL_REGISTRY if getattr(fl, "telemetry", False) else None


def make_eval_fn(model, cfg):
    """Family-appropriate eval metric, jnp-traceable (single source of
    truth for both engines — the scan engine compiles this same function)."""
    if cfg.family == "vision":
        from repro.models.resnet import accuracy

        return lambda p, b: accuracy(p, cfg, b)
    if cfg.family == "trajectory":
        from repro.models.lanegcn import ade, forward

        def f(p, b):
            pred, _ = forward(p, cfg, b["past"], b["lanes"])
            return ade(pred, b["future"])

        return f
    return lambda p, b: model.loss_fn(p, cfg, b)


def evaluate(model, cfg, params, eval_batch) -> float:
    """Family-appropriate eval metric on the global model."""
    return float(make_eval_fn(model, cfg)(params, eval_batch))


def build_provider(fl, policy_name: str, schedule, rounds: int,
                   seed: int) -> ScenarioProvider:
    """Resolve ``schedule`` into a ScenarioProvider, identically for both
    execution engines (loop and scan) so their round inputs are bit-equal.

    ``schedule`` may be None (scenario from the FLConfig), a ready
    ScenarioProvider, or legacy (zeta, tau)[+h2] arrays.  The FedMobile
    relay transform is applied here — it is a schedule-level rewrite.
    """
    if schedule is None:
        provider = ScenarioProvider.from_config(fl, rounds, seed)
    elif isinstance(schedule, ScenarioProvider):
        provider = schedule  # caller-built scenario, reused as-is
    else:  # legacy (zeta, tau) [+ h2] arrays; without h2: i.i.d. gains
        chan = WirelessChannel(
            bandwidth=fl.bandwidth, carrier_ghz=fl.carrier_ghz,
            noise_dbm_hz=fl.noise_dbm_hz, seed=seed + 1,
        )
        provider = ScenarioProvider.from_arrays(*schedule, channel=chan)
    if policy_name == "fedmobile":
        zeta, tau, h2 = provider.schedule()
        zeta, tau = BL.apply_relays(zeta, tau, seed=seed)
        provider = ScenarioProvider.from_arrays(zeta, tau, h2=h2)
    return provider


def sample_budgets(fl, seed: int) -> jax.Array:
    """Per-device energy budgets E_n^con (identical across engines)."""
    rng_np = np.random.default_rng(seed + 2)
    return jnp.asarray(
        rng_np.uniform(*fl.energy_budget, fl.num_devices), jnp.float32
    )


def _round_batch(loader, r: int, shard_key=None):
    """One stacked (N, B, ...) batch; avoids re-wrapping on-device arrays."""
    if shard_key is not None:  # DataShard: already device-resident
        return loader.traced_batch(shard_key, r)
    batch = loader.sample_all()
    return {
        k: v if isinstance(v, jax.Array) else jnp.asarray(v)
        for k, v in batch.items()
    }


def run_afl(
    model,
    cfg,
    fl,
    policy_name: str,
    loader,
    eval_batch,
    rounds: Optional[int] = None,
    eval_every: int = 20,
    seed: Optional[int] = None,
    schedule=None,
    log_progress: bool = False,
    engine: str = "loop",
    telemetry=None,
    tracer=None,
) -> RunResult:
    rounds = rounds or fl.rounds
    seed = fl.seed if seed is None else seed
    telemetry = resolve_telemetry(fl, telemetry, s=model.num_params())

    if engine == "scan":
        from repro.experiments.scan_engine import run_afl_scanned

        return run_afl_scanned(
            model, cfg, fl, policy_name, loader, eval_batch, rounds=rounds,
            eval_every=eval_every, seed=seed, schedule=schedule,
            log_progress=log_progress, telemetry=telemetry, tracer=tracer,
        )
    if engine != "loop":
        raise ValueError(f"unknown engine {engine!r}; known: loop, scan")

    s = model.num_params()
    policy = BL.ALL[policy_name](s, fl)
    provider = build_provider(fl, policy_name, schedule, rounds, seed)
    budgets = sample_budgets(fl, seed)

    state = afl_init(model, cfg, fl, jax.random.key(seed))
    eval_batch = jax.device_put(
        {k: jnp.asarray(v) for k, v in eval_batch.items()}
    )
    hist: dict = {k: [] for k in HIST_KEYS}
    tstate = telemetry.init_state() if telemetry is not None else None
    record = jit_record(telemetry) if telemetry is not None else None

    tot_uploads = tot_k = tot_power = tot_theta = tot_bits = 0.0
    n = fl.num_devices
    shard_key = loader.seed_key(seed) if hasattr(loader, "seed_key") else None
    span = tracer.span if tracer is not None else (
        lambda name, **kw: nullcontext())
    for r in range(rounds):
        batch = _round_batch(loader, r, shard_key)
        zeta_r, tau_r, h2_r = provider.round(r)
        tau_dev = jnp.asarray(tau_r)
        # round 0 pays the afl_round jit compile: separate span name so the
        # compile vs steady-state execute split shows up in the summary
        with span("compile" if r == 0 else "execute"):
            state, m = afl_round(
                state, batch, jnp.asarray(zeta_r), tau_dev,
                jnp.asarray(h2_r, jnp.float32), budgets,
                model=model, cfg=cfg, fl=fl, policy=policy,
            )
            if telemetry is not None:
                tstate = record(tstate, m, tau_dev)
                tstate = record_het(telemetry, tstate,
                                    provider.aux_round(r))
            if tracer is not None:
                tracer.fence(m)
        tot_uploads += float(jnp.sum(m["success"]))
        tot_k += float(jnp.sum(m["k"]))
        tot_power += float(jnp.sum(m["power"]))
        tot_theta += float(jnp.sum(m["theta"]))
        tot_bits += float(jnp.sum(m["bits"]))
        if (r + 1) % eval_every == 0 or r == rounds - 1:
            with span("eval"):
                ev = evaluate(model, cfg, state.w, eval_batch)
            hist["round"].append(r + 1)
            hist["eval"].append(ev)
            hist["uploads"].append(tot_uploads)  # cumulative
            hist["k_mean"].append(tot_k / max(tot_uploads, 1.0))
            hist["energy"].append(float(jnp.sum(state.energy)))
            hist["theta_mean"].append(tot_theta / ((r + 1) * n))
            hist["power_mean"].append(tot_power / max(tot_uploads, 1.0))
            hist["bits_mean"].append(tot_bits / max(tot_uploads, 1.0))
            if log_progress:
                log.info(
                    "policy=%s r=%d eval=%.4f uploads=%.0f k=%.0f E=%.0fJ",
                    policy_name, r + 1, ev, hist["uploads"][-1],
                    hist["k_mean"][-1], hist["energy"][-1],
                )
    snapshot = telemetry.fetch(tstate) if telemetry is not None else None
    return RunResult(policy_name, hist, hist["eval"][-1], state,
                     telemetry=snapshot)
