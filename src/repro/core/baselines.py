"""§VI-B benchmark policies, all running on the Algorithm-1 engine.

1. SFL-Spar   — synchronous FL with sparsification: no local training during
                inter-contact; gradient computed only at contact rounds.
2. AFL        — FedAsync [11]: continuous local training, FULL uploads
                (all-or-nothing: fails when s(u+log2 s) > tau*A), energy-capped.
3. AFL-Spar   — Algorithm 1 with contact-window-filling top-k at fixed max
                power, energy-capped (consumes the budget then stops).
4. FedMobile  — [16]: relaying boosts contact opportunities (schedule-level
                transform: a non-contact device relays through a contacted
                neighbour with probability p_relay, at halved effective
                contact time for the two-hop path); FULL uploads.
5. Optimal    — MADS structure without energy constraints (max feasible
                power, k filling the window) — the paper's upper benchmark.
6. MADS       — the proposed controller (Propositions 1-2 + queues).

Compression-codec policies (beyond-paper; repro/compression): all use the
MADS power controller, so ONLY the codec differs — an apples-to-apples
comparison of how the same tau*A(p) bit budget is spent:

7. MADS-joint — sparsify x quantize, (k, b) split solved in closed form
                per round (`compression.joint`).
8. QSGD       — quantise-everything, bit-width from the budget; no
                sparsification (`compression.qsgd`).
9. fixed-kb   — static (keep-fraction, bit-width) targets clipped to the
                budget (`compression.topk.FixedKbCompressor`).
10. MADS-topk — the Proposition-1 spend routed through the codec API
                (`compression.topk.TopKCompressor` at u=value_bits): the
                codec twin of plain MADS, used by the distributed parity
                suite and as the topk row of codec sweeps.
"""
from __future__ import annotations

import numpy as np

from repro.compression import (
    FixedKbCompressor,
    JointCompressor,
    QSGDCompressor,
    TopKCompressor,
)
from repro.core.afl import Policy, StalenessWeight
from repro.core.mads import MadsController


def _staleness(fl) -> StalenessWeight:
    """The FLConfig-selected alpha * s(delta_tau) aggregation discount.

    Every policy factory threads this through ``Policy.staleness`` so the
    engines AND the streaming ingestion server (repro/serve) share one
    mixing rule; the default (constant, alpha=1) is the identity."""
    return StalenessWeight(
        family=fl.staleness_family,
        alpha=fl.staleness_alpha,
        hinge_a=fl.staleness_hinge_a,
        hinge_b=fl.staleness_hinge_b,
        poly_a=fl.staleness_poly_a,
    )


def _controller(s: int, fl, **kw) -> MadsController:
    return MadsController(
        s=s,
        u=fl.value_bits,
        bandwidth=fl.bandwidth,
        noise_w_hz=10 ** (fl.noise_dbm_hz / 10.0) / 1000.0,
        p_max=fl.max_power,
        v_weight=fl.lyapunov_v,
        **kw,
    )


def mads(s: int, fl) -> Policy:
    return Policy(name="mads", controller=_controller(s, fl),
                  staleness=_staleness(fl))


def optimal(s: int, fl) -> Policy:
    return Policy(
        name="optimal",
        staleness=_staleness(fl),
        controller=_controller(s, fl, energy_unconstrained=True),
    )


def afl_spar(s: int, fl) -> Policy:
    return Policy(
        name="afl-spar",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        fixed_power=fl.max_power,
        energy_capped=True,
    )


def fedasync(s: int, fl) -> Policy:
    return Policy(
        name="afl",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        sparsify=False,
        error_feedback=False,
        fixed_power=fl.max_power,
        energy_capped=True,
    )


def sfl_spar(s: int, fl) -> Policy:
    return Policy(
        name="sfl-spar",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        fixed_power=fl.max_power,
        local_updates=False,
        train_every_round=False,
        energy_capped=True,
    )


def fedmobile(s: int, fl) -> Policy:
    # FedMobile = FedAsync + relays; the relay boost is applied to the
    # (zeta, tau) schedule by ``apply_relays`` below.
    return Policy(
        name="fedmobile",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        sparsify=False,
        error_feedback=False,
        fixed_power=fl.max_power,
        energy_capped=True,
    )


def apply_relays(zeta: np.ndarray, tau: np.ndarray, p_relay: float = 0.3,
                 seed: int = 0):
    """FedMobile schedule transform: a device not in contact may relay its
    update through some contacted device (if any exists that round)."""
    rng = np.random.default_rng(seed)
    zeta = zeta.copy()
    tau = tau.copy()
    rounds, n = zeta.shape
    for r in range(rounds):
        direct = np.flatnonzero(zeta[r])
        if len(direct) == 0:
            continue
        for d in np.flatnonzero(zeta[r] == 0):
            if rng.random() < p_relay:
                helper = rng.choice(direct)
                zeta[r, d] = 1
                tau[r, d] = 0.5 * tau[r, helper]  # two-hop halves the window
    return zeta, tau


def mads_joint(s: int, fl) -> Policy:
    """MADS power + the closed-form joint (k, b) codec.

    ``fl.per_layer_budget`` upgrades the single global split to per-leaf
    (k_l, b_l) pairs (greedy water-filling; `compression.perlayer`)."""
    return Policy(
        name="mads-joint",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        compressor=JointCompressor(
            s=s, method=fl.sparsifier, sample=fl.sample_size,
            b_grid=tuple(range(fl.compress_b_min, fl.compress_b_max + 1)),
            per_layer=fl.per_layer_budget,
        ),
    )


def mads_topk(s: int, fl) -> Policy:
    """MADS power + the top-k codec at the paper's value width.

    The codec twin of plain ``mads``: identical spend (Proposition 1 at
    u = fl.value_bits) but routed through the ``Compressor`` API — the
    apples-to-apples topk row of codec comparisons, and the policy the
    distributed parity suite pins against the seed path."""
    return Policy(
        name="mads-topk",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        compressor=TopKCompressor(
            s=s, method=fl.sparsifier, sample=fl.sample_size,
            u=fl.value_bits,
        ),
    )


def qsgd(s: int, fl) -> Policy:
    """MADS power + dense stochastic quantisation (no sparsification)."""
    return Policy(
        name="qsgd",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        compressor=QSGDCompressor(
            s=s, b_min=fl.compress_b_min, b_max=fl.compress_b_max,
        ),
    )


def fixed_kb(s: int, fl) -> Policy:
    """MADS power + static (k, b) targets clipped to the contact budget."""
    return Policy(
        name="fixed-kb",
        staleness=_staleness(fl),
        controller=_controller(s, fl),
        compressor=FixedKbCompressor(
            s=s, method=fl.sparsifier, sample=fl.sample_size,
            k_frac=fl.fixed_k_frac, b=fl.fixed_bits,
        ),
    )


def mads_no_ef(s: int, fl) -> Policy:
    """Ablation: MADS without the error-feedback memory (dropped residuals).

    Isolates the contribution of e_n (Stich et al. memory) to Algorithm 1 —
    under heavy sparsification the dropped-coordinate mass is lost forever
    without it, degrading convergence (see bench_ablation)."""
    return Policy(
        name="mads-noef",
        staleness=_staleness(fl), controller=_controller(s, fl), error_feedback=False
    )


ALL = {
    "mads": mads,
    "optimal": optimal,
    "afl-spar": afl_spar,
    "afl": fedasync,
    "sfl-spar": sfl_spar,
    "fedmobile": fedmobile,
    "mads-noef": mads_no_ef,
    "mads-joint": mads_joint,
    "mads-topk": mads_topk,
    "qsgd": qsgd,
    "fixed-kb": fixed_kb,
}
