"""Distributed AFL train step (pjit) for the assigned architectures.

The federated mapping at pod scale (DESIGN.md §3/§5):

* clients = mesh slices along the (``pod`` x) ``data`` axes — N = 16 per pod
  (32 at two pods).  The global batch is split evenly among clients.
* per-client state (w_n, g_n, e_n) is stacked on a leading ``client`` axis
  sharded over (``pod``, ``data``); parameter dims are tensor-parallel over
  ``model``.
* the MES global model ``w`` is replicated over (``pod``, ``data``); the
  aggregation  w <- w - (1/N) sum_n zeta_n S(x_n)  contracts the client
  axis, which GSPMD lowers to the hierarchical reduce (within-pod reduce +
  cross-pod all-reduce) — the multi-pod MES synchronisation.
* MADS control (Propositions 1-2) runs per client on scalar contact inputs;
  S(.) is the sampled-quantile threshold mask (static shapes; DESIGN.md §3),
  through the ``sparsify_ef`` fused kernel path on TPU.

``make_afl_train_system`` returns everything the launcher/dry-run needs:
the step fn, state/input shardings, and an abstract state initialiser.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sparsify as SP
from repro.core.mads import MadsController
from repro.sharding import rules as R


class DistAflState(NamedTuple):
    w: Any
    w_n: Any
    g_n: Any
    e_n: Any
    kappa: jax.Array  # (N,)
    q: jax.Array  # (N,)
    energy: jax.Array  # (N,)
    rnd: jax.Array


@dataclasses.dataclass(frozen=True)
class DistConfig:
    num_clients: int
    learning_rate: float = 0.01
    rounds: int = 1000
    sample_size: int = 65536
    value_bits: int = 32
    state_dtype: str = "bfloat16"  # dtype of w_n/g_n/e_n client states
    upload_dtype: str = "float32"  # accumulation dtype of the MES reduce
    accum_dtype: str = "float32"  # local g_n/w_n update arithmetic; "bfloat16"
    # keeps the within-client gradient all-reduce in bf16 (halves its ICI
    # bytes; measured §Perf A3) at ~3-digit accumulate precision — the
    # error-feedback memory absorbs the rounding


def _client_axes(axes):
    return R.prepend_axis(axes, "client")


def mesh_num_clients(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def state_shardings(model, mesh: Mesh, dcfg: DistConfig, rules=None):
    rules = rules or dict(R.RULES_TRAIN, client=[("pod", "data"), ("data",)])
    axes = model.param_axes()
    shapes = R.shapes_tree(model.specs)
    w_sh = R.sharding_tree(axes, shapes, rules, mesh)
    cl_axes = _client_axes(axes)
    cl_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dcfg.num_clients,) + s.shape, s.dtype), shapes
    )
    cl_sh = R.sharding_tree(cl_axes, cl_shapes, rules, mesh)
    rep = NamedSharding(mesh, P())
    return DistAflState(
        w=w_sh, w_n=cl_sh, g_n=cl_sh, e_n=cl_sh,
        kappa=rep, q=rep, energy=rep, rnd=rep,
    )


def abstract_state(model, dcfg: DistConfig):
    """ShapeDtypeStruct pytree of the distributed state (dry-run input)."""
    sdt = jnp.dtype(dcfg.state_dtype)
    shapes = R.shapes_tree(model.specs)
    w = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)
    cl = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dcfg.num_clients,) + s.shape, sdt), shapes
    )
    n = dcfg.num_clients
    f32, i32 = jnp.float32, jnp.int32
    return DistAflState(
        w=w, w_n=cl, g_n=cl, e_n=cl,
        kappa=jax.ShapeDtypeStruct((n,), i32),
        q=jax.ShapeDtypeStruct((n,), f32),
        energy=jax.ShapeDtypeStruct((n,), f32),
        rnd=jax.ShapeDtypeStruct((), i32),
    )


def init_state(model, dcfg: DistConfig, rng) -> DistAflState:
    w = model.init(rng)
    sdt = jnp.dtype(dcfg.state_dtype)
    n = dcfg.num_clients
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None].astype(sdt), (n,) + x.shape), t
    )
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, sdt), t)
    return DistAflState(
        w=w, w_n=stack(w), g_n=zeros(w), e_n=zeros(w),
        kappa=jnp.zeros((n,), jnp.int32), q=jnp.zeros((n,), jnp.float32),
        energy=jnp.zeros((n,), jnp.float32), rnd=jnp.zeros((), jnp.int32),
    )


def _split_clients(batch, n: int):
    """(B, ...) -> (N, B/N, ...) on every leaf."""
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(f, batch)


def make_afl_train_step(model, cfg, dcfg: DistConfig, controller: MadsController):
    """Builds the jittable distributed AFL round."""
    n = dcfg.num_clients
    eta = dcfg.learning_rate

    def step(state: DistAflState, batch, zeta, tau, h2, budgets):
        r = state.rnd + 1
        theta = (r - state.kappa).astype(jnp.float32)

        cl_batch = _split_clients(batch, n)
        grad_fn = jax.vmap(jax.grad(lambda p, b: model.loss_fn(p, cfg, b)))
        grads = grad_fn(state.w_n, cl_batch)

        at = jnp.dtype(dcfg.accum_dtype)
        g_new = jax.tree.map(
            lambda g, d: (g.astype(at) + eta * d.astype(at)).astype(g.dtype),
            state.g_n, grads,
        )
        x = jax.tree.map(lambda e, g: e + g, state.e_n, g_new)
        x_norm2 = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
            for l in jax.tree.leaves(x)
        )

        zf = zeta.astype(jnp.float32)
        k, p, energy = controller.select(zf, theta, x_norm2, state.q, tau, h2)
        ok = zf > 0
        okf = ok.astype(jnp.float32)
        k = k * okf
        energy = energy * okf

        upload, e_after, k_actual = jax.vmap(
            lambda t, kk: SP.sparsify_tree(t, kk, method="sampled", sample=dcfg.sample_size)
        )(x, k)

        # MES aggregation: contract the client axis (hierarchical all-reduce)
        udt = jnp.dtype(dcfg.upload_dtype)
        w_new = jax.tree.map(
            lambda w, up: (
                w.astype(udt)
                - jnp.tensordot(okf.astype(udt), up.astype(udt), axes=(0, 0)) / n
            ).astype(w.dtype),
            state.w, upload,
        )

        bcast = lambda l: jnp.broadcast_to(l[None], (n,) + l.shape)
        cond = lambda c, leaf: c.reshape(c.shape + (1,) * (leaf.ndim - 1))
        sdt = jnp.dtype(dcfg.state_dtype)
        w_n_new = jax.tree.map(
            lambda wn, wg, d: jnp.where(
                cond(ok, wn), bcast(wg).astype(sdt),
                (wn.astype(at) - eta * d.astype(at)).astype(sdt),
            ),
            state.w_n, w_new, grads,
        )
        e_n_new = jax.tree.map(
            lambda new, old: jnp.where(cond(ok, new), new.astype(sdt), old),
            e_after, state.e_n,
        )
        g_n_new = jax.tree.map(
            lambda g: jnp.where(cond(ok, g), jnp.zeros_like(g), g), g_new
        )
        kappa_new = jnp.where(ok, r, state.kappa)
        q_new = controller.queue_update(state.q, energy, budgets, dcfg.rounds)

        metrics = {
            "k": k_actual * okf,
            "power": p * okf,
            "energy": energy,
            "theta": theta,
            "uploads": okf,
            "upload_bits": SP.bits_for_k(k_actual, controller.s, controller.u) * okf,
        }
        return (
            DistAflState(
                w=w_new, w_n=w_n_new, g_n=g_n_new, e_n=e_n_new,
                kappa=kappa_new, q=q_new, energy=state.energy + energy, rnd=r,
            ),
            metrics,
        )

    return step


def run_afl_rounds(step, state, provider, batch_fn, budgets,
                   rounds: int | None = None):
    """Drive a distributed AFL step from a ScenarioProvider.

    ``provider`` is anything yielding per-round (zeta, tau, h2) triples —
    normally ``repro.scenarios.ScenarioProvider`` — and ``batch_fn(r)``
    returns the round's global batch.  Returns (state, metrics history).
    """
    history = []
    for r, (zeta, tau, h2) in enumerate(provider):
        if rounds is not None and r >= rounds:
            break
        state, m = step(
            state, batch_fn(r), jnp.asarray(zeta, jnp.float32),
            jnp.asarray(tau, jnp.float32), jnp.asarray(h2, jnp.float32),
            budgets,
        )
        history.append(m)
    return state, history


def make_afl_train_system(model, cfg, mesh: Mesh, dcfg: DistConfig | None = None,
                          rules=None, controller: MadsController | None = None):
    """Step + shardings bundle for the launcher / dry-run."""
    dcfg = dcfg or DistConfig(num_clients=mesh_num_clients(mesh))
    controller = controller or MadsController(s=model.num_params())
    step = make_afl_train_step(model, cfg, dcfg, controller)
    st_sh = state_shardings(model, mesh, dcfg, rules)
    rep = NamedSharding(mesh, P())
    return {
        "step": step,
        "dcfg": dcfg,
        "controller": controller,
        "state_shardings": st_sh,
        "scalar_sharding": rep,
        "abstract_state": lambda: abstract_state(model, dcfg),
        "init_state": lambda rng: init_state(model, dcfg, rng),
    }
