"""Distributed AFL train step (pjit) for the assigned architectures.

The federated mapping at pod scale (DESIGN.md §3/§5):

* clients = mesh slices along the (``pod`` x) ``data`` axes — N = 16 per pod
  (32 at two pods).  The global batch is split evenly among clients.
* per-client state (w_n, g_n, e_n) is stacked on a leading ``client`` axis
  sharded over (``pod``, ``data``); parameter dims are tensor-parallel over
  ``model``.
* the MES global model ``w`` is replicated over (``pod``, ``data``); the
  aggregation  w <- w - (1/N) sum_n zeta_n S(x_n)  contracts the client
  axis, which GSPMD lowers to the hierarchical reduce (within-pod reduce +
  cross-pod all-reduce) — the multi-pod MES synchronisation.
* MADS control (Propositions 1-2) runs per client on scalar contact inputs;
  S(.) is the sampled-quantile threshold mask (static shapes; DESIGN.md §3),
  through the ``sparsify_ef`` fused kernel path on TPU.
* any ``repro.compression`` codec rides the same step: pass ``compressor``
  and the round spends ``tau * A(p)`` through it instead of the fixed-u
  sparsify path, with the error-feedback memory ``e_n`` and a PRNG carry
  (``DistAflState.ckey``) threading the ``CompressorState`` as sharded
  pytrees.  Shard-safety of the codec's threshold/amax is the sampled
  strided-sample contract (core/README.md): construct codecs with
  ``method="sampled"`` at scale so GSPMD never all-gathers the model.
  The invocation is ``core.afl.compress_uploads`` — the SAME function the
  single-host engines call — so uploads are bit-identical across paths
  (tests/test_distributed_compression.py).

``make_afl_train_system`` returns everything the launcher/dry-run needs:
the step fn, state/input shardings, and an abstract state initialiser.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compression.base import Compressor
from repro.core import mads as M
from repro.core import sparsify as SP
from repro.core.afl import compress_uploads
from repro.core.mads import MadsController
from repro.sharding import rules as R


class DistAflState(NamedTuple):
    w: Any
    w_n: Any
    g_n: Any
    e_n: Any
    kappa: jax.Array  # (N,)
    q: jax.Array  # (N,)
    energy: jax.Array  # (N,)
    rnd: jax.Array
    ckey: jax.Array  # PRNG carry for stochastic codecs (repro/compression)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    num_clients: int
    learning_rate: float = 0.01
    rounds: int = 1000
    sample_size: int = 65536
    value_bits: int = 32
    state_dtype: str = "bfloat16"  # dtype of w_n/g_n/e_n client states
    upload_dtype: str = "float32"  # accumulation dtype of the MES reduce
    accum_dtype: str = "float32"  # local g_n/w_n update arithmetic; "bfloat16"
    # keeps the within-client gradient all-reduce in bf16 (halves its ICI
    # bytes; measured §Perf A3) at ~3-digit accumulate precision — the
    # error-feedback memory absorbs the rounding


def _client_axes(axes):
    return R.prepend_axis(axes, "client")


def mesh_num_clients(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1) * sizes.get("pod", 1)


def state_shardings(model, mesh: Mesh, dcfg: DistConfig, rules=None):
    rules = rules or dict(R.RULES_TRAIN, client=[("pod", "data"), ("data",)])
    axes = model.param_axes()
    shapes = R.shapes_tree(model.specs)
    w_sh = R.sharding_tree(axes, shapes, rules, mesh)
    cl_axes = _client_axes(axes)
    cl_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dcfg.num_clients,) + s.shape, s.dtype), shapes
    )
    cl_sh = R.sharding_tree(cl_axes, cl_shapes, rules, mesh)
    rep = NamedSharding(mesh, P())
    return DistAflState(
        w=w_sh, w_n=cl_sh, g_n=cl_sh, e_n=cl_sh,
        kappa=rep, q=rep, energy=rep, rnd=rep, ckey=rep,
    )


def client_state_shardings(state: DistAflState, mesh: Mesh) -> DistAflState:
    """Leading-client-axis sharding spec for host-device parity runs.

    The global model and scalars replicate; the client-stacked trees take
    the mesh's ``data`` axis on their leading dim.  This is the spec the
    parity suite and ``bench_compression --mesh`` ``device_put`` with —
    production parameter sharding is ``state_shardings`` above.
    """
    rep = NamedSharding(mesh, P())
    cl = NamedSharding(mesh, P("data"))
    return DistAflState(
        w=jax.tree.map(lambda l: rep, state.w),
        w_n=jax.tree.map(lambda l: cl, state.w_n),
        g_n=jax.tree.map(lambda l: cl, state.g_n),
        e_n=jax.tree.map(lambda l: cl, state.e_n),
        kappa=rep, q=rep, energy=rep, rnd=rep, ckey=rep,
    )


def _key_struct():
    """ShapeDtypeStruct of a typed PRNG key without touching devices."""
    return jax.eval_shape(lambda: jax.random.key(0))


def abstract_state(model, dcfg: DistConfig):
    """ShapeDtypeStruct pytree of the distributed state (dry-run input)."""
    sdt = jnp.dtype(dcfg.state_dtype)
    shapes = R.shapes_tree(model.specs)
    w = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), shapes)
    cl = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((dcfg.num_clients,) + s.shape, sdt), shapes
    )
    n = dcfg.num_clients
    f32, i32 = jnp.float32, jnp.int32
    return DistAflState(
        w=w, w_n=cl, g_n=cl, e_n=cl,
        kappa=jax.ShapeDtypeStruct((n,), i32),
        q=jax.ShapeDtypeStruct((n,), f32),
        energy=jax.ShapeDtypeStruct((n,), f32),
        rnd=jax.ShapeDtypeStruct((), i32),
        ckey=_key_struct(),
    )


def init_state(model, dcfg: DistConfig, rng) -> DistAflState:
    w = model.init(rng)
    sdt = jnp.dtype(dcfg.state_dtype)
    n = dcfg.num_clients
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None].astype(sdt), (n,) + x.shape), t
    )
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, sdt), t)
    return DistAflState(
        w=w, w_n=stack(w), g_n=zeros(w), e_n=zeros(w),
        kappa=jnp.zeros((n,), jnp.int32), q=jnp.zeros((n,), jnp.float32),
        energy=jnp.zeros((n,), jnp.float32), rnd=jnp.zeros((), jnp.int32),
        # same derivation as afl.afl_init so the two engines' codecs draw
        # identical dither streams from the same seed
        ckey=jax.random.fold_in(rng, 0x5EED),
    )


def _split_clients(batch, n: int):
    """(B, ...) -> (N, B/N, ...) on every leaf."""
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(f, batch)


def make_afl_train_step(model, cfg, dcfg: DistConfig, controller: MadsController,
                        compressor: Compressor | None = None,
                        telemetry=None, staleness=None):
    """Builds the jittable distributed AFL round.

    ``compressor``: optional ``repro.compression`` codec; when given, the
    upload stage is the codec spending the realised contact capacity
    ``tau * A(p)`` (Proposition 1's left-hand side) with error feedback and
    the PRNG carry threaded through ``DistAflState`` — the same
    ``compress_uploads`` call as the single-host engines, so metrics and
    payloads match.  When None, the legacy fixed-u sampled-threshold path
    runs.

    ``telemetry``: optional ``repro.telemetry.MetricRegistry``.  When
    given, the step takes an extra trailing telemetry-state pytree and
    returns ``(state, metrics, tstate)`` — the accumulation rides the
    pjit program (replicated; histogram counts are exact integers, so the
    sharded client-axis reduce is bit-identical to single host).

    ``staleness``: optional ``core.afl.StalenessWeight`` — the FedAsync
    ``alpha * s(delta_tau)`` aggregation discount applied to the client-
    axis contraction, identical to the single-host ``afl_round`` mixing
    (None or the identity family keeps the paper's constant rule).
    """
    n = dcfg.num_clients
    eta = dcfg.learning_rate
    sw = None if (staleness is None or staleness.is_identity) else staleness

    def step(state: DistAflState, batch, zeta, tau, h2, budgets,
             tstate=None):
        r = state.rnd + 1
        theta = (r - state.kappa).astype(jnp.float32)

        cl_batch = _split_clients(batch, n)
        grad_fn = jax.vmap(jax.grad(lambda p, b: model.loss_fn(p, cfg, b)))
        grads = grad_fn(state.w_n, cl_batch)

        at = jnp.dtype(dcfg.accum_dtype)
        g_new = jax.tree.map(
            lambda g, d: (g.astype(at) + eta * d.astype(at)).astype(g.dtype),
            state.g_n, grads,
        )
        x = jax.tree.map(lambda e, g: e + g, state.e_n, g_new)
        x_norm2 = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
            for l in jax.tree.leaves(x)
        )

        zf = zeta.astype(jnp.float32)
        k, p, energy = controller.select(zf, theta, x_norm2, state.q, tau, h2)
        ok = zf > 0
        okf = ok.astype(jnp.float32)
        k = k * okf
        energy = energy * okf

        if compressor is not None:
            rate = M.rate_bps(p, h2, controller.bandwidth,
                              controller.noise_w_hz)
            budget_bits = tau * rate * okf
            upload, e_after, cstats, ckey = compress_uploads(
                compressor, g_new, state.e_n, state.ckey, budget_bits, n
            )
            k_actual = cstats["k"]
            bits = cstats["bits"] * okf
            b_used = cstats["b"] * okf
        else:
            ckey = state.ckey
            upload, e_after, k_actual = jax.vmap(
                lambda t, kk: SP.sparsify_tree(t, kk, method="sampled",
                                               sample=dcfg.sample_size)
            )(x, k)
            bits = SP.bits_for_k(k_actual, controller.s, controller.u) * okf
            b_used = jnp.full_like(k_actual, float(controller.u)) * okf

        # MES aggregation: contract the client axis (hierarchical all-reduce)
        # with the optional alpha * s(delta_tau) staleness discount — the
        # same mixing weights as afl_round and the serve-path fused ingest
        udt = jnp.dtype(dcfg.upload_dtype)
        mix = okf if sw is None else okf * sw.weight(theta)
        w_new = jax.tree.map(
            lambda w, up: (
                w.astype(udt)
                - jnp.tensordot(mix.astype(udt), up.astype(udt), axes=(0, 0)) / n
            ).astype(w.dtype),
            state.w, upload,
        )

        bcast = lambda l: jnp.broadcast_to(l[None], (n,) + l.shape)
        cond = lambda c, leaf: c.reshape(c.shape + (1,) * (leaf.ndim - 1))
        sdt = jnp.dtype(dcfg.state_dtype)
        w_n_new = jax.tree.map(
            lambda wn, wg, d: jnp.where(
                cond(ok, wn), bcast(wg).astype(sdt),
                (wn.astype(at) - eta * d.astype(at)).astype(sdt),
            ),
            state.w_n, w_new, grads,
        )
        e_n_new = jax.tree.map(
            lambda new, old: jnp.where(cond(ok, new), new.astype(sdt), old),
            e_after, state.e_n,
        )
        g_n_new = jax.tree.map(
            lambda g: jnp.where(cond(ok, g), jnp.zeros_like(g), g), g_new
        )
        kappa_new = jnp.where(ok, r, state.kappa)
        q_new = controller.queue_update(state.q, energy, budgets, dcfg.rounds)

        # same leaf-order reduction as the single-host afl_round so the
        # per-device table / probe accumulators stay engine-comparable
        e_norm2 = sum(
            jnp.sum(jnp.square(l.astype(jnp.float32)),
                    axis=tuple(range(1, l.ndim)))
            for l in jax.tree.leaves(e_n_new)
        )
        metrics = {
            "k": k_actual * okf,
            "success": (k_actual > 0).astype(jnp.float32) * okf,
            "power": p * okf,
            "energy": energy,
            "theta": theta,
            "uploads": okf,
            "x_norm2": x_norm2,
            "e_norm2": e_norm2,
            "bits": bits,  # realised payload (<= tau*A budget; eq. 7c)
            "b": b_used,  # value bit-width on the wire (u, or the codec's b*)
            "upload_bits": bits,  # legacy alias (pre-codec dashboards)
        }
        new_state = DistAflState(
            w=w_new, w_n=w_n_new, g_n=g_n_new, e_n=e_n_new,
            kappa=kappa_new, q=q_new, energy=state.energy + energy, rnd=r,
            ckey=ckey,
        )
        if telemetry is not None:
            from repro.telemetry import record_round

            return new_state, metrics, record_round(telemetry, tstate,
                                                    metrics, tau)
        return new_state, metrics

    return step


def run_afl_rounds(step, state, provider, batch_fn, budgets,
                   rounds: int | None = None, telemetry=None, tstate=None):
    """Drive a distributed AFL step from a ScenarioProvider.

    ``provider`` is anything yielding per-round (zeta, tau, h2) triples —
    normally ``repro.scenarios.ScenarioProvider`` — and ``batch_fn(r)``
    returns the round's global batch.  Returns (state, metrics history);
    with ``telemetry`` (the registry the step was built with) the
    device-resident telemetry state is threaded through every step and
    returned as a third element (fetch it once with ``telemetry.fetch``).
    """
    # budgets are round-invariant: wrap/transfer ONCE, not per round (the
    # same host->device churn bug fixed in core/runner.py in PR 2)
    budgets = budgets if isinstance(budgets, jax.Array) else jnp.asarray(
        budgets, jnp.float32)
    if telemetry is not None and tstate is None:
        tstate = telemetry.init_state()
    # heterogeneity loss masks (when the provider carries the layer) fold
    # into a suite's per-device table alongside each round's metrics
    aux_round = getattr(provider, "aux_round", lambda r: None)
    history = []
    for r, (zeta, tau, h2) in enumerate(provider):
        if rounds is not None and r >= rounds:
            break
        args = (
            state, batch_fn(r), jnp.asarray(zeta, jnp.float32),
            jnp.asarray(tau, jnp.float32), jnp.asarray(h2, jnp.float32),
            budgets,
        )
        if telemetry is not None:
            state, m, tstate = step(*args, tstate)
            from repro.telemetry import record_het

            tstate = record_het(telemetry, tstate, aux_round(r))
        else:
            state, m = step(*args)
        history.append(m)
    if telemetry is not None:
        return state, history, tstate
    return state, history


def scenario_shardings(mesh: Mesh):
    """Sharding specs for device-resident scenario arrays on ``mesh``.

    The (rounds, N) schedule tensors (zeta / tau / h2, and the
    heterogeneity aux masks and (N,) availability state) shard their
    CLIENT axis over the mesh's ``data`` dimension — every downstream
    consumer (the pjit step's client-stacked trees, the per-device
    telemetry rows) is elementwise on that axis, so a client-sharded
    schedule feeds the step with no resharding collectives.  Returns
    ``{"schedule": (rounds, N) spec, "state": (N,) spec}``.
    """
    return {
        "schedule": NamedSharding(mesh, P(None, "data")),
        "state": NamedSharding(mesh, P("data")),
    }


def telemetry_shardings(telemetry, mesh: Mesh):
    """Sharding pytree for a telemetry accumulation state on ``mesh``.

    Registry counters/histograms and probe scalars replicate (their
    updates are full reductions over the client axis, committed
    identically on every shard — integer-exact for the counts).  A
    ``TelemetrySuite``'s per-device table instead takes the mesh's
    ``data`` axis on its (N,) rows: every table update is elementwise per
    client, so each shard accumulates ONLY its own clients' rows and
    GSPMD inserts no mid-run collectives — the rows merge once, at fetch.
    """
    rep = NamedSharding(mesh, P())
    if telemetry is None:
        return rep
    from repro.telemetry import TelemetrySuite

    state = jax.eval_shape(telemetry.init_state)
    if isinstance(telemetry, TelemetrySuite) and telemetry.device is not None:
        cl = NamedSharding(mesh, P("data"))
        out = {k: jax.tree.map(lambda _: rep, v) for k, v in state.items()}
        out["device"] = {f: (cl if s.ndim else rep)
                         for f, s in state["device"].items()}
        return out
    return jax.tree.map(lambda _: rep, state)


def ingest_shardings(mesh: Mesh):
    """Sharding specs for the serve-path fused ingest op on ``mesh``.

    A packed wire batch (``repro.compression.wire.pack_batch``) shards its
    leading BATCH axis over the mesh's ``data`` dimension — decode and the
    per-upload scatter are elementwise on that axis, and the weighted
    client contraction of the aggregation is the only collective (GSPMD
    lowers it to the hierarchical all-reduce, exactly like the train
    step's client-axis reduce).  The global model replicates.  Returns
    ``{"batch": spec for (B, ...) arrays, "w": replicated spec}``.
    """
    return {
        "batch": NamedSharding(mesh, P("data")),
        "w": NamedSharding(mesh, P()),
    }


def make_afl_train_system(model, cfg, mesh: Mesh, dcfg: DistConfig | None = None,
                          rules=None, controller: MadsController | None = None,
                          compressor: Compressor | None = None,
                          telemetry=None, staleness=None):
    """Step + shardings bundle for the launcher / dry-run."""
    dcfg = dcfg or DistConfig(num_clients=mesh_num_clients(mesh))
    controller = controller or MadsController(s=model.num_params())
    step = make_afl_train_step(model, cfg, dcfg, controller,
                               compressor=compressor, telemetry=telemetry,
                               staleness=staleness)
    st_sh = state_shardings(model, mesh, dcfg, rules)
    rep = NamedSharding(mesh, P())
    return {
        "step": step,
        "dcfg": dcfg,
        "controller": controller,
        "compressor": compressor,
        "telemetry": telemetry,
        "state_shardings": st_sh,
        "scalar_sharding": rep,
        # registry state replicates (integer-exact histogram counts commit
        # the same value on every shard); a suite's per-device rows shard
        # over the client mesh — see telemetry_shardings
        "telemetry_sharding": telemetry_shardings(telemetry, mesh),
        "abstract_state": lambda: abstract_state(model, dcfg),
        "init_state": lambda rng: init_state(model, dcfg, rng),
    }
