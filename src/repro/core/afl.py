"""Algorithm 1 — the AFL training process (simulation mode).

One jitted ``afl_round`` advances the whole federation by one round:
all N devices compute stochastic gradients (vmapped), the contacted subset
uploads sparsified cumulative gradients with error feedback, the MES
aggregates, and staleness / virtual-energy-queue bookkeeping advances.

The upload policy (who sends what, at which k and p) is pluggable — MADS
and every §VI-B baseline are policies over the same engine, so benchmark
comparisons differ only in the policy, exactly like the paper's setup.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.compression.base import Compressor, CompressorState
from repro.core import mads as M
from repro.core import sparsify as SP
from repro.core.mads import MadsController


class AflState(NamedTuple):
    w: Any  # global model pytree
    w_n: Any  # per-device models, leaves stacked on leading N
    g_n: Any  # cumulative gradients (eta-scaled), stacked
    e_n: Any  # error memory, stacked
    kappa: jax.Array  # (N,) last global-model reception round
    q: jax.Array  # (N,) virtual energy queues
    energy: jax.Array  # (N,) cumulative energy spent
    rnd: jax.Array  # scalar round index r
    ckey: jax.Array  # PRNG key for stochastic codecs (repro/compression)


@dataclasses.dataclass(frozen=True)
class StalenessWeight:
    """The FedAsync ``alpha * s(delta_tau)`` staleness-discount family.

    The paper's MES mixes every upload at a constant weight; Xie et al.'s
    asynchronous-optimization line generalises the rule to a staleness-
    dependent discount ``alpha * s(delta_tau)`` with ``s`` drawn from:

    * ``constant``: ``s = 1``            (the paper's rule at ``alpha``)
    * ``hinge``:    ``s = 1`` while ``delta_tau <= hinge_b``, then
                    ``1 / (hinge_a * (delta_tau - hinge_b))``
    * ``poly``:     ``s = (delta_tau + 1) ** -poly_a``

    Frozen/hashable so it rides ``Policy`` (and the serve-path ingest op)
    as a jit static argument.  The default — constant at ``alpha = 1`` —
    is the identity: engines skip the multiply entirely (``is_identity``
    is a compile-time branch), so existing programs are unchanged.
    """

    family: str = "constant"  # constant | hinge | poly
    alpha: float = 1.0
    hinge_a: float = 10.0
    hinge_b: float = 4.0
    poly_a: float = 0.5

    FAMILIES = ("constant", "hinge", "poly")

    @property
    def is_identity(self) -> bool:
        return self.family == "constant" and self.alpha == 1.0

    def s(self, delta_tau):
        """The undiscounted ``s(delta_tau)`` term (jnp-traceable)."""
        dt = jnp.asarray(delta_tau, jnp.float32)
        if self.family == "constant":
            return jnp.ones_like(dt)
        if self.family == "hinge":
            return jnp.where(
                dt <= self.hinge_b, 1.0,
                1.0 / (self.hinge_a * jnp.maximum(dt - self.hinge_b, 1e-9)),
            )
        if self.family == "poly":
            return (dt + 1.0) ** (-self.poly_a)
        raise ValueError(
            f"unknown staleness family {self.family!r}; "
            f"known: {self.FAMILIES}")

    def weight(self, delta_tau):
        """``alpha * s(delta_tau)`` — the aggregation mixing weight."""
        return self.alpha * self.s(delta_tau)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Engine flags + (k, p) selection strategy."""

    name: str = "mads"
    controller: MadsController | None = None
    sparsify: bool = True  # False -> all-or-nothing full upload
    error_feedback: bool = True
    local_updates: bool = True  # SGD during inter-contact (False: SFL)
    train_every_round: bool = True  # False: gradient only at contact (SFL)
    energy_capped: bool = False  # hard stop when budget exhausted (AFL/AFL-Spar)
    fixed_power: float = 0.0  # >0: transmit at this power (non-MADS baselines)
    # None -> the seed top-k-at-32-bit path below; a repro.compression codec
    # replaces the sparsify/quantize stage and spends tau*A(p) bits itself
    compressor: Compressor | None = None
    # staleness-discounted aggregation weight alpha * s(delta_tau) shared
    # by every engine AND the streaming ingestion server (repro/serve) —
    # the default is the identity (the paper's constant rule at alpha=1)
    staleness: StalenessWeight = StalenessWeight()
    # True -> afl_round also returns the dense upload payloads under
    # metrics["upload"] (N-stacked tree).  Test/serve plumbing only: the
    # serve parity suite feeds the SAME uploads through the wire format
    # and the fused ingest op.  Engines leave this False (the scan engine
    # would otherwise buffer (rounds, N, s) payloads)
    expose_uploads: bool = False

    def select(self, ctl: MadsController, zeta, theta, x_norm2, q, tau, h2):
        if self.controller is not None and self.fixed_power <= 0:
            return self.controller.select(zeta, theta, x_norm2, q, tau, h2)
        # fixed-power policies: k fills the contact window at power p_fix
        p = jnp.full_like(tau, self.fixed_power) * zeta
        k = M.mads_k(p, tau, h2, ctl.s, ctl.u, ctl.bandwidth, ctl.noise_w_hz) * zeta
        if not self.sparsify:
            # full upload or nothing: feasible iff s fits in tau * A
            feasible = k >= ctl.s
            k = jnp.where(feasible, float(ctl.s), 0.0)
            bits = SP.bits_for_k(k, ctl.s, ctl.u)
            a = M.rate_bps(p, h2, ctl.bandwidth, ctl.noise_w_hz)
            energy = jnp.where(feasible, p * bits / jnp.maximum(a, 1e-9), 0.0)
            return k, p * feasible, energy
        energy = p * tau
        return k, p, energy


def compress_uploads(comp: Compressor, g_n, e_n, ckey, budget_bits, n: int):
    """One codec pass over the federation — shared by BOTH engines.

    The single-host ``afl_round`` below and the pjit distributed step
    (``core/distributed.py``) call this same function, so the key
    splitting, per-device vmap, and ``CompressorState`` threading are
    identical — which is what makes their uploads bit-identical (the
    parity suite in tests/test_distributed_compression.py pins this).

    Returns ``(upload, e_after, cstats, ckey)``: the dense dequantised
    payloads, the error-feedback memories, the per-device ``{"k", "bits",
    "b"}`` stats, and the advanced PRNG carry.
    """
    ckey, sub = jax.random.split(ckey)
    dev_keys = jax.random.split(sub, n)
    upload, cstate, cstats = jax.vmap(comp.compress)(
        g_n, budget_bits, CompressorState(error=e_n, key=dev_keys)
    )
    return upload, cstate.error, cstats, ckey


def _bcast_to(cond, leaf):
    return cond.reshape(cond.shape + (1,) * (leaf.ndim - 1))


def _select(cond, a, b):
    """Per-device select over stacked pytrees. cond: (N,) 0/1."""
    return jax.tree.map(lambda x, y: jnp.where(_bcast_to(cond, x) != 0, x, y), a, b)


def afl_init(model, cfg, fl, rng) -> AflState:
    w = model.init(rng)
    n = fl.num_devices
    stack = lambda t: jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), t)
    return AflState(
        w=w,
        w_n=stack(w),
        g_n=zeros(w),
        e_n=zeros(w),
        kappa=jnp.zeros((n,), jnp.int32),
        q=jnp.zeros((n,), jnp.float32),
        energy=jnp.zeros((n,), jnp.float32),
        rnd=jnp.zeros((), jnp.int32),
        ckey=jax.random.fold_in(rng, 0x5EED),
    )


@partial(jax.jit, static_argnames=("model", "cfg", "fl", "policy"))
def afl_round(state: AflState, batch, zeta, tau, h2, energy_budget,
              *, model, cfg, fl, policy: Policy) -> tuple[AflState, dict]:
    """One round r of Algorithm 1.

    batch: stacked per-device minibatches (leading N); zeta (N,) 0/1;
    tau (N,) contact durations; h2 (N,) channel gains;
    energy_budget (N,) E_n^con.
    """
    n = fl.num_devices
    eta = fl.learning_rate
    ctl = policy.controller or MadsController(s=model.num_params())
    r = state.rnd + 1
    theta = (r - state.kappa).astype(jnp.float32)

    # --- local stochastic gradients (all devices, vmapped) -----------------
    grad_fn = jax.vmap(jax.grad(lambda p, b: model.loss_fn(p, cfg, b)))
    grads = grad_fn(state.w_n, batch)
    if not policy.train_every_round:
        grads = jax.tree.map(lambda g: g * _bcast_to(zeta.astype(g.dtype), g), grads)

    g_new = jax.tree.map(lambda g, d: g + eta * d.astype(g.dtype), state.g_n, grads)

    # --- upload decision (MADS or baseline policy) --------------------------
    x = jax.tree.map(jnp.add, state.e_n, g_new)
    x_norm2 = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in jax.tree.leaves(x)
    )
    zf = zeta.astype(jnp.float32)
    k, p, energy = policy.select(ctl, zf, theta, x_norm2, state.q, tau, h2)
    ok = zf > 0
    if policy.energy_capped:
        ok = ok & (state.energy + energy <= energy_budget)
    k = k * ok
    energy = energy * ok
    okf = ok.astype(jnp.float32)

    # --- compression with error feedback -----------------------------------
    if policy.compressor is not None:
        # codec path: the budget is the realised contact capacity tau*A(p)
        # (Proposition 1's left-hand side); the codec decides how to spend
        # it (k, b, or both) and returns the EF residual as its state
        rate = M.rate_bps(p, h2, ctl.bandwidth, ctl.noise_w_hz)
        budget_bits = tau * rate * okf
        upload, e_after, cstats, ckey = compress_uploads(
            policy.compressor, g_new, state.e_n, state.ckey, budget_bits, n
        )
        k_actual = cstats["k"]
        bits = cstats["bits"] * okf
        b_used = cstats["b"] * okf
    else:
        # seed path: top-k at fixed ctl.u-bit values (paper §III-D)
        ckey = state.ckey
        upload, e_after, k_actual = jax.vmap(
            lambda t, kk: SP.sparsify_tree(t, kk, method=fl.sparsifier, sample=fl.sample_size)
        )(x, k)
        if ctl.u < 32:  # quantized wire format: EF absorbs the residual too
            upload_q = jax.vmap(lambda t: SP.quantize_values(t, ctl.u))(upload)
            e_after = jax.tree.map(lambda e, u, uq: e + (u - uq), e_after, upload, upload_q)
            upload = upload_q
        bits = SP.bits_for_k(k_actual, ctl.s, ctl.u) * okf
        b_used = jnp.full_like(k_actual, float(ctl.u)) * okf
    if not policy.error_feedback:
        e_after = jax.tree.map(jnp.zeros_like, e_after)

    # --- MES aggregation: w <- w - (1/N) sum a s(theta) zeta S(x_n) ---------
    # mixing weight: the FedAsync alpha * s(delta_tau) staleness discount;
    # the default family is the identity (compile-time branch), keeping the
    # paper's constant rule — and the serve-path fused ingest op applies
    # the SAME weights, which is what makes the two paths bit-comparable
    mix = okf if policy.staleness.is_identity \
        else okf * policy.staleness.weight(theta)
    w_new = jax.tree.map(
        lambda w, up: (
            w - (jnp.tensordot(mix, up.astype(jnp.float32), axes=(0, 0)) / n).astype(w.dtype)
        ),
        state.w,
        upload,
    )

    # --- device-side state transitions --------------------------------------
    w_local = (
        jax.tree.map(lambda wn, d: wn - eta * d.astype(wn.dtype), state.w_n, grads)
        if policy.local_updates
        else state.w_n
    )
    w_bcast = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), w_new)
    w_n_new = _select(okf, w_bcast, w_local)
    e_n_new = _select(okf, e_after, state.e_n)
    g_n_new = _select(okf, jax.tree.map(jnp.zeros_like, g_new), g_new)
    kappa_new = jnp.where(ok, r, state.kappa)
    q_new = ctl.queue_update(state.q, energy, energy_budget, fl.rounds)

    # per-device EF-memory squared norm (Lemma 4's E||e_n||^2, observable):
    # same leaf-order reduction as x_norm2 so engines agree bit-for-bit
    e_norm2 = sum(
        jnp.sum(jnp.square(l.astype(jnp.float32)), axis=tuple(range(1, l.ndim)))
        for l in jax.tree.leaves(e_n_new)
    )
    metrics = {
        "k": k_actual * okf,
        "k_target": k,
        "success": (k_actual > 0).astype(jnp.float32) * okf,
        "power": p * okf,
        "energy": energy,
        "theta": theta,
        "uploads": okf,
        "x_norm2": x_norm2,
        "e_norm2": e_norm2,
        "queue": q_new,
        "bits": bits,  # realised upload payload (<= tau*A budget; eq. 7c)
        "b": b_used,  # value bit-width on the wire (u, or the codec's b*)
    }
    if policy.expose_uploads:
        # serve-parity plumbing: the dense payloads the MES just applied,
        # plus the quantisation step a wire encoder needs to turn them
        # back into grid codes (compression/wire.py; 1.0 = raw floats)
        metrics["upload"] = upload
        metrics["upload_step"] = (
            cstats["step"] if policy.compressor is not None
            else jnp.ones((n,), jnp.float32))
    new_state = AflState(
        w=w_new, w_n=w_n_new, g_n=g_n_new, e_n=e_n_new,
        kappa=kappa_new, q=q_new, energy=state.energy + energy, rnd=r,
        ckey=ckey,
    )
    return new_state, metrics
