"""Batch iteration utilities for the federated simulation."""
from __future__ import annotations

import numpy as np


def batch_iterator(arrays: dict, batch_size: int, seed: int = 0):
    """Infinite shuffled mini-batch iterator over a dict of same-length arrays."""
    n = len(next(iter(arrays.values())))
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i : i + batch_size]
            yield {k: v[sel] for k, v in arrays.items()}


class DeviceLoader:
    """Per-device mini-batch sampler (device n draws B_n^(r) from D_n)."""

    def __init__(self, device_arrays: list[dict], batch_size: int, seed: int = 0):
        self._iters = [
            batch_iterator(arrs, batch_size, seed + 7 * i)
            for i, arrs in enumerate(device_arrays)
        ]

    def __len__(self):
        return len(self._iters)

    def sample(self, device: int) -> dict:
        return next(self._iters[device])

    def sample_all(self) -> dict:
        """Stacked batch for all devices: leaves get a leading device axis."""
        batches = [next(it) for it in self._iters]
        return {
            k: np.stack([b[k] for b in batches], axis=0) for k in batches[0]
        }
