"""Synthetic datasets standing in for CIFAR-10 / Argoverse / token corpora.

The container is offline, so the paper's datasets are replaced by generators
with the same shapes and a *learnable signal*:

* ``SyntheticCifar`` — class-conditional images: each class has a fixed
  random template; samples are template + Gaussian noise.  A model that
  learns the 10 templates reaches high accuracy, so FL convergence dynamics
  (the paper's object of study) are preserved.
* ``SyntheticTrajectories`` — kinematic vehicle tracks (constant-turn-rate +
  noise) with lane-center-line context; target = next 30 positions @10 Hz,
  metric = ADE (paper §VI-C).
* ``SyntheticTokens`` — order-k Markov token streams for the LLM examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticCifar:
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    noise: float = 0.35
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = rng.normal(
            0, 1, (self.num_classes, self.image_size, self.image_size, self.channels)
        ).astype(np.float32)

    def sample(self, rng: np.random.Generator, labels: np.ndarray):
        imgs = self.templates[labels] + rng.normal(
            0, self.noise, (len(labels), self.image_size, self.image_size, self.channels)
        ).astype(np.float32)
        return imgs

    def make_split(self, n: int, class_probs: np.ndarray | None = None, seed: int = 1):
        """Draw n (image, label) pairs with the given class mixture."""
        rng = np.random.default_rng(seed)
        p = class_probs if class_probs is not None else np.full(self.num_classes, 1 / self.num_classes)
        labels = rng.choice(self.num_classes, size=n, p=p / p.sum())
        return self.sample(rng, labels), labels.astype(np.int32)


@dataclasses.dataclass
class SyntheticTrajectories:
    """Argoverse-like motion forecasting: 20 past -> 30 future steps @10Hz."""

    past: int = 20
    future: int = 30
    map_nodes: int = 32
    dt: float = 0.1
    seed: int = 0

    def make_split(self, n: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        speed = rng.uniform(3.0, 20.0, (n, 1))
        heading0 = rng.uniform(-np.pi, np.pi, (n, 1))
        turn = rng.normal(0.0, 0.08, (n, 1))  # rad/s
        t = np.arange(self.past + self.future) * self.dt
        heading = heading0 + turn * t[None, :]
        vx = speed * np.cos(heading)
        vy = speed * np.sin(heading)
        x = np.cumsum(vx * self.dt, axis=1)
        y = np.cumsum(vy * self.dt, axis=1)
        traj = np.stack([x, y], axis=-1).astype(np.float32)
        traj += rng.normal(0, 0.05, traj.shape).astype(np.float32)
        # centre on the last observed position (Argoverse convention)
        traj = traj - traj[:, self.past - 1 : self.past, :]
        past, future = traj[:, : self.past], traj[:, self.past :]
        # lane centreline context: noisy extrapolation of the heading
        s = np.linspace(0, 3.0, self.map_nodes)[None, :, None]
        lane_dir = np.stack([np.cos(heading[:, self.past - 1]), np.sin(heading[:, self.past - 1])], -1)
        lanes = (s * lane_dir[:, None, :] * speed[:, :, None]).astype(np.float32)
        lanes += rng.normal(0, 0.2, lanes.shape).astype(np.float32)
        return {"past": past, "lanes": lanes, "future": future.astype(np.float32)}


@dataclasses.dataclass
class SyntheticTokens:
    """Order-1 Markov chain over the vocab with a low-rank transition."""

    vocab_size: int = 1024
    rank: int = 8
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        a = rng.normal(0, 1, (self.vocab_size, self.rank))
        b = rng.normal(0, 1, (self.rank, self.vocab_size))
        logits = a @ b / np.sqrt(self.rank)
        self.probs = np.exp(logits - logits.max(-1, keepdims=True))
        self.probs /= self.probs.sum(-1, keepdims=True)

    def make_split(self, n: int, seq_len: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        out = np.zeros((n, seq_len + 1), np.int32)
        out[:, 0] = rng.integers(0, self.vocab_size, n)
        cdf = np.cumsum(self.probs, axis=-1)
        for t in range(seq_len):
            u = rng.random(n)
            out[:, t + 1] = (u[:, None] < cdf[out[:, t]]).argmax(-1)
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}
