from repro.data.partition import dirichlet_partition, gamma_class_proportions
from repro.data.synthetic import (
    SyntheticCifar,
    SyntheticTokens,
    SyntheticTrajectories,
)
from repro.data.loader import DeviceLoader, batch_iterator

__all__ = [
    "SyntheticCifar",
    "SyntheticTokens",
    "SyntheticTrajectories",
    "dirichlet_partition",
    "gamma_class_proportions",
    "DeviceLoader",
    "batch_iterator",
]
