"""Non-i.i.d. data partitioning (paper §VI).

The paper draws per-device class proportions Z_i = z_i / sum(z) with
z_i ~ Gamma(rho * Zbar_i, 1) — i.e. a Dirichlet(rho * Zbar) mixture.
Small rho => near single-class devices; large rho => i.i.d.
"""
from __future__ import annotations

import numpy as np


def gamma_class_proportions(
    num_devices: int, class_prior: np.ndarray, rho: float, seed: int = 0
) -> np.ndarray:
    """(num_devices, num_classes) row-stochastic class mixtures (paper's model)."""
    rng = np.random.default_rng(seed)
    shape = np.maximum(rho * np.asarray(class_prior, np.float64), 1e-6)
    z = rng.gamma(shape=np.broadcast_to(shape, (num_devices, len(class_prior))), scale=1.0)
    z = np.maximum(z, 1e-12)
    return (z / z.sum(axis=1, keepdims=True)).astype(np.float32)


def dirichlet_partition(
    labels: np.ndarray, num_devices: int, rho: float, seed: int = 0
) -> list[np.ndarray]:
    """Split sample indices across devices with Dirichlet(rho) class mixtures."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    prior = np.array([np.mean(labels == c) for c in classes])
    mix = gamma_class_proportions(num_devices, prior, rho, seed)
    per_class = {c: rng.permutation(np.flatnonzero(labels == c)) for c in classes}
    offsets = {c: 0 for c in classes}
    n_per_dev = len(labels) // num_devices
    out = []
    for d in range(num_devices):
        want = (mix[d] * n_per_dev).astype(int)
        want[-1] = max(n_per_dev - want[:-1].sum(), 0)
        idx = []
        for c, w in zip(classes, want):
            pool = per_class[c]
            take = pool[offsets[c] : offsets[c] + w]
            # wrap around if a class is exhausted (keeps sizes equal)
            if len(take) < w:
                take = np.concatenate([take, pool[: w - len(take)]])
            offsets[c] = (offsets[c] + w) % max(len(pool), 1)
            idx.append(take)
        out.append(rng.permutation(np.concatenate(idx)).astype(np.int64))
    return out
