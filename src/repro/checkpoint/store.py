"""Flat-npz pytree checkpointing with step directories.

Layout: <dir>/step_<n>/arrays.npz + tree.json (key paths + dtypes).
No external deps; adequate for the CPU-scale drivers.  Arrays are written
via ``np.savez`` with '/'-joined key paths.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, arr in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        if node and all(re.fullmatch(r"#\d+", k) for k in node):
            return [rebuild(node[f"#{i}"]) for i in range(len(node))]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save(directory: str, step: int, tree) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    meta = {k: str(v.dtype) for k, v in flat.items()}
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump({"step": step, "dtypes": meta}, f)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isfile(os.path.join(directory, d, "arrays.npz"))
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int | None = None):
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat), step
