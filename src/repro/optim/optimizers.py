"""Minimal optimizer library (optax-style pure functions, no dependency).

The paper trains with plain SGD (lr 0.01); AdamW/momentum are provided for
the beyond-paper drivers. State and updates are pytrees matching params.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state); updates are SUBTRACTED


def sgd(lr) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["count"]
        updates = jax.tree.map(lambda g: lr_fn(step) * g, grads)
        return updates, {"count": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params=None):
        step = state["count"]
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: lr_fn(step) * (beta * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: lr_fn(step) * m, mu)
        return upd, {"count": step + 1, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["count"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": step, "m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p - u.astype(p.dtype)).astype(p.dtype), params, updates)
