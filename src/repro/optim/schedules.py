"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_decay(peak: float, decay_steps: int, floor: float = 0.0):
    def fn(step):
        t = jnp.clip(step / max(decay_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))

    return fn


def warmup_cosine(peak: float, warmup_steps: int, decay_steps: int, floor: float = 0.0):
    cos = cosine_decay(peak, max(decay_steps - warmup_steps, 1), floor)

    def fn(step):
        warm = peak * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn
