"""Exporters: JSONL event sink, and the benchmark trajectory files that
``tools/bench_compare.py`` gates regressions against.

The sink buffers events host-side and lands them with the same atomic
write-then-rename discipline as ``experiments/results.py`` — a kill
mid-flush can never leave a truncated file that downstream tooling would
half-parse.

Benchmark rows (the ``name,us_per_call,derived`` CSV every bench module
prints) export as ``BENCH_<suite>.json``: parsed rows plus a bounded
trajectory of previous exports to the same path, so a workstation or CI
artifact accumulates the suite's history.
"""
from __future__ import annotations

import json
import logging
import math
import os
from typing import Iterable, Optional

log = logging.getLogger("repro.telemetry.export")

MAX_BENCH_HISTORY = 20  # previous exports kept in a BENCH file


def _atomic_write(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# JSONL events
# ---------------------------------------------------------------------------


def sanitize(obj):
    """Replace non-finite floats with ``None`` recursively.

    Returns ``(clean, n_replaced)``.  ``json.dumps`` would happily emit
    ``NaN``/``Infinity`` — which is NOT valid JSON and breaks every strict
    reader of ``telemetry.jsonl`` — so the sink nulls them out instead of
    letting one diverged metric poison a whole sweep's artifact.  Only
    python floats are sanitised; callers convert device/numpy scalars via
    ``to_jsonable`` first (non-serialisable objects still fail eagerly).
    """
    if isinstance(obj, float):
        return (obj, 0) if math.isfinite(obj) else (None, 1)
    if isinstance(obj, dict):
        n = 0
        out = {}
        for k, v in obj.items():
            out[k], dn = sanitize(v)
            n += dn
        return out, n
    if isinstance(obj, (list, tuple)):
        n = 0
        items = []
        for v in obj:
            cv, dn = sanitize(v)
            items.append(cv)
            n += dn
        return (items if isinstance(obj, list) else tuple(items)), n
    return obj, 0


class JsonlSink:
    """Buffered JSONL writer with atomic flush (write-then-rename).

    Events are plain dicts; ``emit`` validates JSON-serialisability
    eagerly so a bad record fails at the call site, not at flush time.
    Non-finite floats are sanitised to ``null`` with a warning (a NaN'd
    counter mid-sweep must not kill the sweep or corrupt the JSONL).
    Usable as a context manager (flushes on exit).
    """

    def __init__(self, path: str):
        self.path = path
        self.events: list[dict] = []

    def emit(self, record: dict) -> None:
        record, bad = sanitize(record)
        if bad:
            log.warning("sanitized %d non-finite value(s) to null in %r "
                        "event", bad, record.get("kind", "?"))
        json.dumps(record, allow_nan=False)  # fail fast on non-jsonable
        self.events.append(record)

    def extend(self, records: Iterable[dict]) -> None:
        for r in records:
            self.emit(r)

    def flush(self) -> str:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        _atomic_write(
            self.path, "".join(json.dumps(r) + "\n" for r in self.events)
        )
        return self.path

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()


def read_jsonl(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Benchmark trajectory files
# ---------------------------------------------------------------------------


def parse_csv_row(row: str) -> dict:
    """``name,us_per_call,derived`` -> a record with parsed metrics.

    The derived field is ``key=value`` pairs joined by ``;`` (values may
    carry a trailing ``x`` multiplier suffix); non-numeric values are kept
    verbatim under ``derived`` only.
    """
    name, us, derived = (row.split(",", 2) + ["", ""])[:3]
    rec: dict = {"name": name, "us_per_call": float(us or 0.0),
                 "derived": derived, "metrics": {}}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        try:
            rec["metrics"][key.strip()] = float(val.strip().rstrip("x"))
        except ValueError:
            pass
    return rec


def export_bench(suite: str, rows, out_dir: str = ".",
                 meta: Optional[dict] = None) -> str:
    """Write ``BENCH_<suite>.json`` (atomically) under ``out_dir``.

    ``rows``: CSV strings from a bench module's ``run()`` or pre-parsed
    record dicts.  If the file already exists, its latest rows are pushed
    onto a bounded ``history`` list — the regression *trajectory*.
    """
    recs = [parse_csv_row(r) if isinstance(r, str) else dict(r)
            for r in rows]
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    history = []
    if os.path.exists(path):
        try:
            prev = load_bench(path)
            history = prev.get("history", [])[-(MAX_BENCH_HISTORY - 1):]
            history.append({"meta": prev.get("meta", {}),
                            "rows": prev.get("rows", [])})
        except (json.JSONDecodeError, OSError):
            history = []
    payload = {"suite": suite, "schema": 1, "meta": meta or {},
               "rows": recs, "history": history}
    _atomic_write(path, json.dumps(payload, indent=2) + "\n")
    return path


def load_bench(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
