"""Online theory-vs-practice probes: does a run match the closed forms?

The paper's convergence story (core/theory.py) rests on three measurable
quantities MADS optimizes against:

* the **sparsification-error fraction** ``E[(s - k)/s]`` per contact
  (Lemma 3 / ``theory.expected_error_fraction``),
* the **staleness second moment** ``E[theta^2]`` at upload (Lemma 2 /
  ``theory.staleness_second_moment``),
* the **upload success rate** ``P(k >= 1)`` — Lemma 3's survival factor
  ``theory.gamma`` (for tau ~ Exp(c) and Proposition-1 spend,
  ``P(tau * A >= u + log2 s) = exp(-(u + log2 s)/(A c)) = gamma``).

``TheoryProbes`` accumulates the measured counterparts DURING the run as a
pytree of scalar f32 sums — carried through ``lax.scan``, the pjit step,
and the vmapped seed axis with the same zero-mid-run-host-sync contract as
``MetricRegistry`` — and ``report`` compares them at fetch against the
closed forms, emitting per-term ``measured / expected / delta`` records
plus a Theorem-1 bound decomposition (t1..t4, from the online
``coupling_sum`` / ``theta2_all_sum`` accumulators that mirror the
round-wise sums in ``theory.theorem1_rhs``).  A run thus self-reports when
practice drifts from the theory MADS assumes — e.g. when the mobility
model's contact-time distribution stops being exponential, or a codec's
realized k diverges from the Proposition-1 spend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

import jax.numpy as jnp

from repro.core import theory

#: scalar accumulators in the probe state, all merged by addition
PROBE_FIELDS = (
    "rounds",             # rounds advanced
    "contacts",           # sum okf
    "successes",          # sum success
    "err_frac_sum",       # sum over contacts of (s - k)/s
    "theta2_contact_sum",  # sum theta^2 over contacted devices (Lemma 2)
    "theta2_all_sum",     # sum theta^2 over ALL devices (Theorem 1 t3)
    "coupling_sum",       # sum okf * theta * (5 - 3k/s) * ||x||^2 (t2)
    "tau_sum",            # sum tau over contacts (measured mean c)
    "rate_sum",           # sum bits/tau over successes (measured mean A)
    "bits_sum",           # sum realized bits
)


@dataclasses.dataclass(frozen=True)
class TheoryProbes:
    """Probe spec (frozen + hashable: part of the engines' jit-cache keys).

    ``s`` is the model size, ``u`` the value bit-width — the same (s, u)
    the run's ``MadsController``/codec spends with, so measured and
    expected terms share one operating point.
    """

    s: int
    u: int = 32

    # -- state ---------------------------------------------------------------

    def init_state(self) -> dict:
        return {f: jnp.zeros((), jnp.float32) for f in PROBE_FIELDS}

    # -- update (jnp-traceable) ----------------------------------------------

    def update(self, state: dict, metrics: Mapping, tau) -> dict:
        """Fold one round's metric dict in.  Uses uploads/success/theta/
        k/bits (all engines emit these) plus ``x_norm2`` when present
        (needed only for the Theorem-1 coupling term)."""
        okf = jnp.asarray(metrics["uploads"], jnp.float32)
        succ = jnp.asarray(metrics["success"], jnp.float32)
        theta = jnp.asarray(metrics["theta"], jnp.float32)
        k = jnp.asarray(metrics["k"], jnp.float32)
        bits = jnp.asarray(metrics["bits"], jnp.float32)
        tau = jnp.asarray(tau, jnp.float32)
        x2 = metrics.get("x_norm2")
        x2 = (jnp.asarray(x2, jnp.float32) if x2 is not None
              else jnp.zeros_like(theta))
        s = float(self.s)
        return {
            "rounds": state["rounds"] + 1.0,
            "contacts": state["contacts"] + jnp.sum(okf),
            "successes": state["successes"] + jnp.sum(succ),
            "err_frac_sum": state["err_frac_sum"]
            + jnp.sum(okf * (s - k) / s),
            "theta2_contact_sum": state["theta2_contact_sum"]
            + jnp.sum(okf * theta**2),
            "theta2_all_sum": state["theta2_all_sum"] + jnp.sum(theta**2),
            "coupling_sum": state["coupling_sum"]
            + jnp.sum(okf * theta * (5.0 - 3.0 * k / s) * x2),
            "tau_sum": state["tau_sum"] + jnp.sum(okf * tau),
            "rate_sum": state["rate_sum"]
            + jnp.sum(succ * bits / jnp.maximum(tau, 1e-9)),
            "bits_sum": state["bits_sum"] + jnp.sum(bits),
        }

    # -- merge ---------------------------------------------------------------

    def merge(self, a: dict, b: dict) -> dict:
        return {f: a[f] + b[f] for f in a}

    def merge_stacked(self, state: dict, axis: int = 0) -> dict:
        return {f: jnp.sum(state[f], axis=axis) for f in state}

    # -- host side -----------------------------------------------------------

    def fetch(self, state: dict) -> dict:
        return {f: float(state[f]) for f in PROBE_FIELDS}

    def measured(self, snapshot: dict) -> dict:
        """Measured means from a fetched (or JSONL-loaded) probe state."""
        contacts = max(snapshot["contacts"], 1.0)
        successes = max(snapshot["successes"], 1.0)
        n_dev_rounds = max(snapshot["rounds"], 1.0)
        return {
            "error_fraction": snapshot["err_frac_sum"] / contacts,
            "staleness_second_moment":
                snapshot["theta2_contact_sum"] / contacts,
            "success_rate": snapshot["successes"]
            / max(snapshot["contacts"], 1.0),
            "mean_tau": snapshot["tau_sum"] / contacts,
            "mean_rate": snapshot["rate_sum"] / successes,
            "rounds": n_dev_rounds,
        }

    def report(self, snapshot: dict, *, c: float, lam: float, delta: float,
               rate: Optional[float] = None, f0_gap: float = 1.0,
               big_l: float = 1.0, g2: float = 1.0, sigma: float = 1.0,
               n: Optional[int] = None) -> dict:
        """Theory-vs-measured comparison at the run's operating point.

        ``c``/``lam``/``delta`` are the contact model parameters the closed
        forms assume (``contact_params(fl)`` derives them from an
        FLConfig).  ``rate`` is the link rate A (bit/s) the theory is
        evaluated at; by default the run's *measured* mean upload rate —
        the self-calibrating choice, so deltas isolate distributional
        drift rather than rate mis-specification.  The Theorem-1 terms use
        ``n`` devices (``report_from_config`` supplies ``fl.num_devices``)
        and the standard-constant defaults for (f0_gap, L, G^2, sigma).
        """
        m = self.measured(snapshot)
        rate = float(rate) if rate else max(m["mean_rate"], 1.0)
        terms = {}

        expected_err = theory.expected_error_fraction(rate, c, self.s,
                                                      self.u)
        terms["error_fraction"] = _term(m["error_fraction"], expected_err)

        bound_theta2 = theory.staleness_second_moment(c, lam, delta)
        terms["staleness_second_moment"] = _term(
            m["staleness_second_moment"], bound_theta2)

        gam = theory.gamma(rate, c, self.s, self.u)
        terms["success_rate"] = _term(m["success_rate"], gam)

        # Theorem-1 bound decomposition from the online accumulators
        rounds = max(snapshot["rounds"], 1.0)
        n = max(int(n), 1) if n is not None else 1
        eta_ref = 1.0 / (big_l * math.sqrt(rounds))  # Theorem-2 step size
        t1 = 4.0 * f0_gap / (eta_ref * rounds)
        t2 = 4.0 * big_l**2 / (n * rounds) * snapshot["coupling_sum"]
        t3 = (8.0 * eta_ref**2 * big_l**2 * g2 / (n * rounds)
              * snapshot["theta2_all_sum"])
        t4 = 4.0 * eta_ref * big_l * sigma / n
        theorem1 = {
            "t1_init_gap": t1,
            "t2_sparsify_staleness_coupling": t2,
            "t3_staleness_sq": t3,
            "t4_grad_noise": t4,
            "total": t1 + t2 + t3 + t4,
        }
        return {
            "s": self.s, "u": self.u, "c": c, "lam": lam, "delta": delta,
            "rate": rate, "terms": terms, "theorem1": theorem1,
            "measured": m,
        }

    def summary(self, report: dict) -> str:
        """Terminal theory-vs-measured table from a ``report`` dict."""
        lines = [f"{'probe':<26s} {'measured':>12s} {'expected':>12s} "
                 f"{'delta':>12s} {'rel':>8s}"]
        for name, t in report["terms"].items():
            lines.append(
                f"{name:<26s} {t['measured']:>12.4g} {t['expected']:>12.4g} "
                f"{t['delta']:>+12.4g} {t['rel']:>+8.1%}"
            )
        th = report["theorem1"]
        lines.append("theorem1 bound decomposition: "
                     + "  ".join(f"{k}={v:.4g}" for k, v in th.items()))
        return "\n".join(lines)


def _term(measured: float, expected: float) -> dict:
    return {
        "measured": float(measured),
        "expected": float(expected),
        "delta": float(measured - expected),
        "rel": float((measured - expected) / expected) if expected else
        float("inf"),
    }


def contact_params(fl) -> tuple[float, float, float]:
    """(c, lam, delta) the closed forms assume, from an FLConfig — the
    same speed scaling ``ContactProcess.from_speed`` applies."""
    if fl.speed > 0:
        v = max(fl.speed, 1e-6)
        return fl.contact_const / v, fl.intercontact_const / v, \
            fl.round_duration
    return fl.mean_contact, fl.mean_intercontact, fl.round_duration


def report_from_config(probes: TheoryProbes, snapshot: dict, fl,
                       **kw) -> dict:
    """``TheoryProbes.report`` with (c, lam, delta, n) read off an
    FLConfig — the one-liner the launch layer calls."""
    c, lam, delta = contact_params(fl)
    kw.setdefault("n", fl.num_devices)
    return probes.report(snapshot, c=c, lam=lam, delta=delta, **kw)


def probes_to_jsonable(snapshot: Optional[dict]) -> Optional[dict]:
    if snapshot is None:
        return None
    return {f: float(v) for f, v in snapshot.items()}


__all__ = [
    "PROBE_FIELDS",
    "TheoryProbes",
    "contact_params",
    "probes_to_jsonable",
    "report_from_config",
]
