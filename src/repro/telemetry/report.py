"""Run reports: ``telemetry.jsonl`` (+ bench trajectories) -> markdown.

``render_report`` turns the event stream a sweep/train run lands in its
JSONL sink — phase spans from ``PhaseTracer``, merged ``metrics`` /
``group_metrics`` snapshots (registry or suite-sectioned), per-group
``probe_report`` records — into ONE self-contained markdown document:
phase-time breakdown (nested spans indented under their parent), counter
tables, ASCII histograms of the registry distributions, the per-device
straggler table, the theory-vs-measured probe table, and the
``BENCH_<suite>.json`` throughput trajectory.  ``tools/report.py`` is the
CLI wrapper; CI renders the smoke sweep's report as a build artifact.

Everything here is host-side string assembly over already-fetched
snapshots — nothing imports back into the compiled engines.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from repro.telemetry.metrics import AFL_REGISTRY, merge_fetched
from repro.telemetry.perdevice import participation_gini, top_stragglers


# ---------------------------------------------------------------------------
# ASCII histograms
# ---------------------------------------------------------------------------


def _fmt_edge(e: float) -> str:
    return f"{e:g}" if abs(e) < 1e5 else f"{e:.1e}"


def bin_labels(num_bins: int, edges: Optional[Iterable[float]]) -> list[str]:
    """Under/interior/overflow labels matching ``Histogram`` semantics;
    generic ``bin i`` labels when the edges are unknown."""
    edges = list(edges) if edges is not None else None
    if edges is None or len(edges) + 1 != num_bins:
        return [f"bin {i}" for i in range(num_bins)]
    lab = [f"< {_fmt_edge(edges[0])}"]
    lab += [f"[{_fmt_edge(a)}, {_fmt_edge(b)})"
            for a, b in zip(edges[:-1], edges[1:])]
    lab.append(f">= {_fmt_edge(edges[-1])}")
    return lab


def ascii_hist(counts, edges=None, width: int = 40) -> list[str]:
    """Render binned counts as label-aligned ASCII bars."""
    c = np.asarray(counts, np.float64)
    labels = bin_labels(len(c), edges)
    peak = float(c.max()) if len(c) else 0.0
    lw = max(len(s) for s in labels)
    out = []
    for label, v in zip(labels, c):
        bar = "#" * (int(round(v / peak * width)) if peak > 0 else 0)
        out.append(f"{label:>{lw}s} | {bar:<{width}s} {v:g}")
    return out


def _registry_edges(name: str):
    for h in AFL_REGISTRY.histograms:
        if h.name == name:
            return h.edges
    return None


# ---------------------------------------------------------------------------
# Section renderers (each returns a list of markdown lines, possibly empty)
# ---------------------------------------------------------------------------


def _md_table(header: list[str], rows: list[list]) -> list[str]:
    fmt = lambda v: (f"{v:.4g}" if isinstance(v, float) else str(v))
    return (["| " + " | ".join(header) + " |",
             "|" + "---|" * len(header)]
            + ["| " + " | ".join(fmt(v) for v in r) + " |" for r in rows])


def _phase_section(spans: list[dict]) -> list[str]:
    if not spans:
        return []
    # aggregate by (name, parent) so nested spans group under their parent
    agg: dict = {}
    order: list = []
    for s in spans:
        key = (s.get("parent"), s["name"])
        if key not in agg:
            agg[key] = {"count": 0, "total": 0.0, "max": 0.0, "errors": 0,
                        "depth": int(s.get("depth", 0))}
            order.append(key)
        a = agg[key]
        a["count"] += 1
        a["total"] += float(s.get("duration_s", 0.0))
        a["max"] = max(a["max"], float(s.get("duration_s", 0.0)))
        a["errors"] += 1 if s.get("error") else 0
    # parents first, their children directly beneath
    order.sort(key=lambda k: (agg[k]["depth"], -agg[k]["total"]))
    rows = []
    for parent, name in order:
        a = agg[(parent, name)]
        label = ("&nbsp;&nbsp;↳ " * min(a["depth"], 1) + name
                 if parent else name)
        note = f" ({a['errors']} raised)" if a["errors"] else ""
        rows.append([label + note, a["count"], a["total"],
                     a["total"] / a["count"] * 1e3, a["max"] * 1e3])
    return (["## Phase breakdown", ""]
            + _md_table(["phase", "count", "total s", "mean ms", "max ms"],
                        rows) + [""])


def _registry_section(snap: Optional[dict]) -> list[str]:
    if snap is None:
        return []
    out = ["## Federation counters", ""]
    rows = [[k, float(v)] for k, v in snap["counters"].items()]
    sc = snap["counters"]
    if "successes" in sc and "contacts" in sc:
        rows.append(["success_rate",
                     float(sc["successes"]) / max(float(sc["contacts"]), 1.0)])
    rows += [[f"{k} (gauge)", float(v)] for k, v in snap["gauges"].items()]
    out += _md_table(["metric", "value"], rows) + [""]
    out += ["## Distributions", ""]
    for name, counts in snap["hist"].items():
        out.append(f"### {name}")
        out.append("```")
        out += ascii_hist(counts, _registry_edges(name))
        out += ["```", ""]
    return out


def _groups_section(groups: list[dict]) -> list[str]:
    if not groups:
        return []
    rows = []
    for g in groups:
        snap = g.get("metrics") if "metrics" in g else g
        if "counters" not in (snap or {}):
            continue
        c = snap["counters"]
        contacts = float(c.get("contacts", 0.0))
        rows.append([
            g.get("group", "?"), int(g.get("seeds", 1)),
            float(c.get("rounds", 0.0)), contacts,
            float(c.get("successes", 0.0)),
            float(c.get("successes", 0.0)) / max(contacts, 1.0),
            float(c.get("bits_total", 0.0)) / 1e6,
        ])
    if not rows:
        return []
    return (["## Per-group results", ""]
            + _md_table(["group", "seeds", "rounds", "contacts", "successes",
                         "success rate", "Mbits"], rows) + [""])


def _straggler_section(device: Optional[dict], k: int = 8) -> list[str]:
    if device is None:
        return []
    rows = [
        [r["device"], r["contacts"], r["successes"], r["failures"],
         r["success_rate"], r["staleness_mean"], r["last_contact"],
         r["bits_sum"] / 1e6, r["energy_sum"]]
        for r in top_stragglers(device, k=k)
    ]
    gini = participation_gini(device)
    return (["## Stragglers (per-device flight recorder)", "",
             f"Participation Gini: **{gini:.3f}** "
             "(0 = uniform, 1 = one device does everything).", ""]
            + _md_table(["device", "contacts", "succ", "fail", "succ rate",
                         "stale mean", "last round", "Mbits", "J"], rows)
            + [""])


def _probes_section(reports: list[dict]) -> list[str]:
    if not reports:
        return []
    out = ["## Theory vs measured (online probes)", ""]
    for rep in reports:
        group = rep.get("group")
        if group:
            out.append(f"### {group}")
        out.append(
            f"Operating point: s={rep.get('s')} u={rep.get('u')} "
            f"c={rep.get('c'):.4g} lam={rep.get('lam'):.4g} "
            f"delta={rep.get('delta'):.4g} rate={rep.get('rate'):.4g} bit/s"
        )
        out.append("")
        rows = [[name, t["measured"], t["expected"], t["delta"], t["rel"]]
                for name, t in rep.get("terms", {}).items()]
        out += _md_table(["probe", "measured", "expected", "delta", "rel"],
                         rows)
        th = rep.get("theorem1")
        if th:
            out.append("")
            out.append("Theorem-1 bound decomposition: "
                       + "  ".join(f"{k}={v:.4g}" for k, v in th.items()))
        out.append("")
    return out


def _bench_section(bench: Optional[dict]) -> list[str]:
    if not bench:
        return []
    out = [f"## Bench trajectory ({bench.get('suite', '?')})", ""]
    history = bench.get("history", [])
    rows = []
    for rec in bench.get("rows", []):
        trail = [
            r["us_per_call"] for h in history for r in h.get("rows", [])
            if r.get("name") == rec.get("name")
        ]
        rows.append([
            rec.get("name", "?"), float(rec.get("us_per_call", 0.0)),
            rec.get("metrics", {}).get("rounds_per_s", ""),
            " → ".join(f"{v:.0f}" for v in trail) or "(first export)",
        ])
    return out + _md_table(
        ["bench", "us/call", "rounds/s", "history (us/call)"], rows) + [""]


# ---------------------------------------------------------------------------
# The report
# ---------------------------------------------------------------------------


def _suite_sections(ev: dict):
    """(registry snapshot, device snapshot, probes snapshot) from a
    ``metrics`` event — suite-sectioned or plain registry."""
    if "counters" in ev:
        return ev, None, None
    return ev.get("metrics"), ev.get("device"), ev.get("probes")


def render_report(events: list[dict], bench: Optional[dict] = None,
                  title: str = "Run report") -> str:
    """Assemble the markdown report from JSONL events (+ optional BENCH).

    Understands the event kinds train/sweep emit: ``span``, ``metrics``
    (sweep-wide total), ``group_metrics``, ``probe_report``.  Missing
    kinds simply drop their section — a loop-engine train run without
    probes still gets phases + counters + histograms.
    """
    spans = [e for e in events if e.get("kind") == "span"]
    totals = [e for e in events if e.get("kind") == "metrics"]
    groups = [e for e in events if e.get("kind") == "group_metrics"]
    probe_reports = [e for e in events if e.get("kind") == "probe_report"]

    if totals:
        registry, device, probes = _suite_sections(totals[-1])
    elif groups:
        merged = merge_fetched([
            {k: v for k, v in g.items() if k not in ("kind", "group",
                                                     "seeds")}
            for g in groups
        ])
        registry, device, probes = _suite_sections(merged)
    else:
        registry = device = probes = None

    lines = [f"# {title}", "",
             f"_{len(events)} telemetry events; {len(spans)} spans, "
             f"{len(groups)} group snapshot(s), {len(probe_reports)} probe "
             "report(s)._", ""]
    lines += _phase_section(spans)
    lines += _registry_section(registry)
    lines += _groups_section(groups)
    lines += _straggler_section(device)
    lines += _probes_section(probe_reports)
    lines += _bench_section(bench)
    return "\n".join(lines).rstrip() + "\n"


__all__ = ["ascii_hist", "bin_labels", "render_report"]
