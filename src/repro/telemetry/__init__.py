"""Telemetry: device-resident round metrics, phase tracing, exporters.

See README.md here for the metric catalog and the scan/pjit carry
contract.  Quick map:

* ``metrics``  — ``MetricRegistry`` (counters / gauges / fixed-bin
  histograms) whose state is a pytree carried through ``lax.scan``, the
  pjit step, vmapped seeds, and mesh shards; ``AFL_REGISTRY`` +
  ``record_round`` are the built-in Algorithm-1 instrumentation.
* ``tracing``  — ``PhaseTracer`` wall-clock spans with
  ``block_until_ready`` fencing and optional ``jax.profiler`` hooks.
* ``export``   — atomic JSONL event sink, ``BENCH_<suite>.json``
  trajectory files (gated by ``tools/bench_compare.py``).
"""
from repro.telemetry.export import (
    JsonlSink,
    export_bench,
    load_bench,
    parse_csv_row,
    read_jsonl,
)
from repro.telemetry.metrics import (
    AFL_REGISTRY,
    HIST_KEYS,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    afl_registry,
    jit_record,
    merge_fetched,
    record_round,
    to_jsonable,
)
from repro.telemetry.tracing import PhaseTracer, Span

__all__ = [
    "AFL_REGISTRY",
    "HIST_KEYS",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MetricRegistry",
    "PhaseTracer",
    "Span",
    "afl_registry",
    "export_bench",
    "jit_record",
    "load_bench",
    "merge_fetched",
    "parse_csv_row",
    "read_jsonl",
    "record_round",
    "to_jsonable",
]
