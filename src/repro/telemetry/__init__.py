"""Telemetry: device-resident round metrics, phase tracing, exporters.

See README.md here for the metric catalog and the scan/pjit carry
contract.  Quick map:

* ``metrics``   — ``MetricRegistry`` (counters / gauges / fixed-bin
  histograms) whose state is a pytree carried through ``lax.scan``, the
  pjit step, vmapped seeds, and mesh shards; ``AFL_REGISTRY`` +
  ``record_round`` are the built-in Algorithm-1 instrumentation;
  ``TelemetrySuite`` composes the registry with the layers below under
  one carry.
* ``perdevice`` — ``DeviceTable`` per-client flight recorder ((N,) rows:
  participation, staleness, tau, bits, energy, EF norm) with top-k
  straggler extraction at fetch.
* ``probes``    — ``TheoryProbes`` online theory-vs-practice accumulators
  compared against ``core/theory.py`` closed forms at fetch.
* ``tracing``   — ``PhaseTracer`` wall-clock spans (nested, exception-
  safe) with ``block_until_ready`` fencing and ``jax.profiler`` hooks.
* ``export``    — atomic JSONL event sink (NaN/inf sanitised to null),
  ``BENCH_<suite>.json`` trajectory files (``tools/bench_compare.py``).
* ``report``    — ``render_report``: telemetry.jsonl + snapshots ->
  self-contained markdown run report (``tools/report.py`` CLI).
"""
from repro.telemetry.export import (
    JsonlSink,
    export_bench,
    load_bench,
    parse_csv_row,
    read_jsonl,
    sanitize,
)
from repro.telemetry.metrics import (
    AFL_REGISTRY,
    HIST_KEYS,
    SERVE_REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    TelemetrySuite,
    afl_registry,
    jit_record,
    merge_fetched,
    record_het,
    record_ingest,
    record_round,
    serve_registry,
    to_jsonable,
)
from repro.telemetry.perdevice import (
    DeviceTable,
    participation_gini,
    table_to_jsonable,
    top_by,
    top_stragglers,
)
from repro.telemetry.probes import (
    TheoryProbes,
    contact_params,
    probes_to_jsonable,
    report_from_config,
)
from repro.telemetry.report import ascii_hist, render_report
from repro.telemetry.tracing import PhaseTracer, Span

__all__ = [
    "AFL_REGISTRY",
    "HIST_KEYS",
    "SERVE_REGISTRY",
    "Counter",
    "DeviceTable",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MetricRegistry",
    "PhaseTracer",
    "Span",
    "TelemetrySuite",
    "TheoryProbes",
    "afl_registry",
    "ascii_hist",
    "contact_params",
    "export_bench",
    "jit_record",
    "load_bench",
    "merge_fetched",
    "parse_csv_row",
    "participation_gini",
    "probes_to_jsonable",
    "read_jsonl",
    "record_het",
    "record_ingest",
    "record_round",
    "serve_registry",
    "render_report",
    "report_from_config",
    "sanitize",
    "table_to_jsonable",
    "to_jsonable",
    "top_by",
    "top_stragglers",
]
