"""Per-device flight recorder: a ``DeviceTable`` of client-resident metrics.

``MetricRegistry`` (metrics.py) answers *what the federation did*;
``DeviceTable`` answers *which device did it*.  Its accumulation state is a
dict of ``(N, ...)`` jnp arrays — one row per client — carried exactly like
the registry state: through the ``lax.scan`` body of the compiled engine,
the pjit distributed step (shard the rows over the client mesh with
``core.distributed.telemetry_shardings``; every update is elementwise per
client, so GSPMD inserts NO collectives mid-run and the rows merge only at
fetch), and the vmapped seed axis (leading ``(S, N, ...)`` batch).  Zero
host round-trips mid-run; ``fetch`` is the one sync, same contract as
``MetricRegistry``.

Bit-identity: the count-like fields (``contacts``, ``successes``,
``failures``, ``last_contact``, ``staleness_sum``, ``staleness_max``) are
sums/maxima of exact-integer-valued f32 updates applied elementwise in
round order — no cross-device reduction ever happens, so the loop runner,
the scan engine, and the (sharded) pjit step produce *bit-identical*
tables for the same seeded run (tests/test_telemetry.py).  Float fields
(``tau_sum``, ``bits_sum``, ``energy_sum``, ``e_norm2``) are also
elementwise accumulations and agree bitwise whenever the per-round metric
values do (pinned by the distributed parity suite).

Host-side, ``rows``/``top_stragglers``/``top_by`` turn a fetched table
into per-device records and top-k straggler/outlier extractions — the
debugging substrate for "which devices starve" questions that global
aggregates cannot answer.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

# merge semantics per field (used by merge/merge_stacked and by
# metrics.merge_fetched for the host-side JSONL mirror):
#   sum — accumulators add across seeds/shards
#   max — last-value / extremum fields take the maximum
FIELD_KIND = {
    "rounds": "sum",
    "contacts": "sum",
    "successes": "sum",
    "failures": "sum",
    "last_contact": "max",
    "staleness_sum": "sum",
    "staleness_max": "max",
    "tau_sum": "sum",
    "bits_sum": "sum",
    "energy_sum": "sum",
    "e_norm2": "max",
    # heterogeneity loss counters (scenarios/heterogeneity): contacts a
    # client lost to unavailability or a dropout.  Zero unless the scenario
    # carries a HeterogeneityModel; folded in by ``update_het``, NOT by
    # ``update`` (the engine metric dicts never contain them)
    "unavail": "sum",
    "dropouts": "sum",
}

#: per-device (N,) fields, in state order; "rounds" is the extra scalar
DEVICE_FIELDS = tuple(k for k in FIELD_KIND if k != "rounds")


@dataclasses.dataclass(frozen=True)
class DeviceTable:
    """Per-client flight-recorder spec (frozen + hashable: a table keys
    the engines' jit caches exactly like ``MetricRegistry``).

    ``n`` is the federation size; every per-device field is an ``(n,)``
    f32 array in the accumulation state.
    """

    n: int

    # -- state ---------------------------------------------------------------

    def init_state(self) -> dict:
        state = {f: jnp.zeros((self.n,), jnp.float32) for f in DEVICE_FIELDS}
        state["rounds"] = jnp.zeros((), jnp.float32)
        return state

    # -- update (jnp-traceable, elementwise per client) ----------------------

    def update(self, state: dict, metrics: Mapping, tau) -> dict:
        """Fold one round's engine metric dict into the table.

        Uses only keys all three execution paths emit (``afl_round``, the
        scan body, the distributed step): uploads/success/theta/bits/
        energy, plus ``e_norm2`` (EF-memory squared norm) when present.
        Every update is elementwise on the client axis — the property that
        keeps a client-sharded table collective-free until fetch.
        """
        okf = jnp.asarray(metrics["uploads"], jnp.float32)
        succ = jnp.asarray(metrics["success"], jnp.float32)
        theta = jnp.asarray(metrics["theta"], jnp.float32)
        tau = jnp.asarray(tau, jnp.float32)
        r = state["rounds"] + 1.0
        new = {
            "rounds": r,
            "contacts": state["contacts"] + okf,
            "successes": state["successes"] + succ,
            "failures": state["failures"] + (okf - succ),
            "last_contact": jnp.where(okf > 0, r, state["last_contact"]),
            "staleness_sum": state["staleness_sum"] + theta * okf,
            "staleness_max": jnp.maximum(state["staleness_max"], theta * okf),
            "tau_sum": state["tau_sum"] + tau * okf,
            "bits_sum": state["bits_sum"]
            + jnp.asarray(metrics["bits"], jnp.float32),
            "energy_sum": state["energy_sum"]
            + jnp.asarray(metrics["energy"], jnp.float32),
        }
        # EF-memory norm: last value wins (a gauge per client); engines
        # that do not emit it leave the previous value in place
        e2 = metrics.get("e_norm2")
        new["e_norm2"] = (
            jnp.asarray(e2, jnp.float32) if e2 is not None
            else state["e_norm2"]
        )
        # het counters ride through unchanged: update_het owns them
        new["unavail"] = state["unavail"]
        new["dropouts"] = state["dropouts"]
        return new

    def update_het(self, state: dict, het: Optional[Mapping]) -> dict:
        """Fold one round's heterogeneity loss masks into the table.

        ``het`` is a ``ScenarioProvider.aux_round`` dict — (N,) 0/1 masks
        under "unavail" / "dropout" — or None (layer disabled: no-op).
        Elementwise per client, same collective-free property as ``update``.
        """
        if het is None:
            return state
        new = dict(state)
        new["unavail"] = state["unavail"] \
            + jnp.asarray(het["unavail"], jnp.float32)
        new["dropouts"] = state["dropouts"] \
            + jnp.asarray(het["dropout"], jnp.float32)
        return new

    # -- merge ---------------------------------------------------------------

    def merge(self, a: dict, b: dict) -> dict:
        """Combine two tables (seeds / shards): sums add, maxima max."""
        return {
            f: (jnp.add if FIELD_KIND[f] == "sum" else jnp.maximum)(
                a[f], b[f])
            for f in a
        }

    def merge_stacked(self, state: dict, axis: int = 0) -> dict:
        """Collapse a leading batch axis (vmapped seeds, stacked shards)."""
        return {
            f: (jnp.sum if FIELD_KIND[f] == "sum" else jnp.max)(
                state[f], axis=axis)
            for f in state
        }

    # -- host side -----------------------------------------------------------

    def fetch(self, state: dict) -> dict:
        """Device state -> host snapshot (np arrays + float rounds)."""
        out = {f: np.asarray(state[f]) for f in DEVICE_FIELDS}
        out["rounds"] = float(state["rounds"])
        return out

    def summary(self, snapshot: dict, k: int = 5) -> str:
        """Terminal table of the k worst stragglers."""
        lines = [f"{'device':>6s} {'contacts':>9s} {'succ':>6s} "
                 f"{'fail':>6s} {'stale_mean':>11s} {'last_r':>7s} "
                 f"{'Mbits':>8s} {'J':>8s}"]
        for row in top_stragglers(snapshot, k=k):
            lines.append(
                f"{row['device']:>6d} {row['contacts']:>9.0f} "
                f"{row['successes']:>6.0f} {row['failures']:>6.0f} "
                f"{row['staleness_mean']:>11.2f} {row['last_contact']:>7.0f} "
                f"{row['bits_sum'] / 1e6:>8.2f} {row['energy_sum']:>8.2f}"
            )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Host-side row extraction: stragglers and outliers
# ---------------------------------------------------------------------------


def rows(snapshot: dict) -> list[dict]:
    """Fetched table -> one record per device, with derived stats."""
    n = len(np.asarray(snapshot["contacts"]))
    # het counters: absent from snapshots fetched before the heterogeneity
    # layer existed (archived telemetry.jsonl)
    unavail = np.asarray(snapshot.get("unavail", np.zeros(n)))
    dropouts = np.asarray(snapshot.get("dropouts", np.zeros(n)))
    out = []
    for i in range(n):
        contacts = float(np.asarray(snapshot["contacts"])[i])
        succ = float(np.asarray(snapshot["successes"])[i])
        rec = {
            "device": i,
            "contacts": contacts,
            "successes": succ,
            "failures": float(np.asarray(snapshot["failures"])[i]),
            "success_rate": succ / max(contacts, 1.0),
            "last_contact": float(np.asarray(snapshot["last_contact"])[i]),
            "staleness_mean":
                float(np.asarray(snapshot["staleness_sum"])[i])
                / max(contacts, 1.0),
            "staleness_max": float(np.asarray(snapshot["staleness_max"])[i]),
            "tau_mean": float(np.asarray(snapshot["tau_sum"])[i])
            / max(contacts, 1.0),
            "bits_sum": float(np.asarray(snapshot["bits_sum"])[i]),
            "energy_sum": float(np.asarray(snapshot["energy_sum"])[i]),
            "e_norm2": float(np.asarray(snapshot["e_norm2"])[i]),
            "unavail": float(unavail[i]),
            "dropouts": float(dropouts[i]),
        }
        out.append(rec)
    return out


def top_by(snapshot: dict, field: str, k: int = 5,
           largest: bool = True) -> list[dict]:
    """Top-k outlier devices by any derived row field."""
    recs = rows(snapshot)
    recs.sort(key=lambda r: r[field], reverse=largest)
    return recs[:k]


def top_stragglers(snapshot: dict, k: int = 5) -> list[dict]:
    """The k most starved devices: fewest participations first, oldest
    last-contact breaking ties, then highest mean staleness."""
    recs = rows(snapshot)
    recs.sort(key=lambda r: (r["contacts"], r["last_contact"],
                             -r["staleness_mean"]))
    return recs[:k]


def participation_gini(snapshot: dict) -> float:
    """Gini coefficient of per-device participation counts (0 = uniform,
    1 = one device does everything) — a one-number starvation signal."""
    c = np.sort(np.asarray(snapshot["contacts"], np.float64))
    n = len(c)
    total = c.sum()
    if n == 0 or total <= 0:
        return 0.0
    cum = np.cumsum(c)
    return float((n + 1 - 2.0 * cum.sum() / total) / n)


def table_to_jsonable(snapshot: Optional[dict]) -> Optional[dict]:
    """Fetched table -> plain lists/floats for the JSONL sink."""
    if snapshot is None:
        return None
    return {
        f: ([float(x) for x in np.asarray(v)]
            if np.ndim(v) else float(v))
        for f, v in snapshot.items()
    }


# imported lazily by jit-traced paths; kept here for API symmetry
__all__ = [
    "DEVICE_FIELDS",
    "DeviceTable",
    "FIELD_KIND",
    "participation_gini",
    "rows",
    "table_to_jsonable",
    "top_by",
    "top_stragglers",
]
