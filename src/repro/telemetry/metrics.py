"""Device-resident metrics: counters, gauges, and fixed-bin histograms.

The accumulation state of a :class:`MetricRegistry` is a plain pytree of
``jnp`` arrays, so it can be carried through ``lax.scan`` (the compiled
experiment engine), threaded through the pjit distributed step, vmapped
over seeds, and sharded over a mesh — with ZERO host round-trips mid-run.
The state is fetched ONCE at run end (``fetch``) and merged across
vmapped seeds / mesh shards (``merge`` / ``merge_stacked``).

Bit-identity contract: histogram bin counts and the round/contact/success
counters are sums of 0/1 weights, i.e. exact integers in float32 — their
value is independent of the reduction order XLA picks, which is what lets
the loop runner, the scan engine, and the (sharded) pjit step emit
*bit-identical* histograms for the same seeded run
(tests/test_telemetry.py).  Float-valued counters (``bits_total``,
``energy_total``) are exact only up to reduction order.

``HIST_KEYS`` — the per-eval-point history keys both execution engines
emit — also lives here as the single source of truth (it used to be
duplicated between ``core/runner.py`` and ``experiments/scan_engine.py``).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Per-eval-point history keys emitted by BOTH execution engines
# (core/runner.py loop and experiments/scan_engine.py).  Single source of
# truth — the engines and the results store import it from here.
HIST_KEYS = (
    "round", "eval", "uploads", "k_mean", "energy", "theta_mean",
    "power_mean", "bits_mean"
)


# ---------------------------------------------------------------------------
# Metric specs (hashable: registries key jit caches)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Counter:
    """Monotone accumulator (sums of per-round increments)."""

    name: str
    doc: str = ""


@dataclasses.dataclass(frozen=True)
class Gauge:
    """Last-value-wins scalar (e.g. the current round index)."""

    name: str
    doc: str = ""


@dataclasses.dataclass(frozen=True)
class Histogram:
    """Fixed-bin histogram.  ``edges`` are the ascending interior edges;
    the state holds ``len(edges) + 1`` bins: an underflow bin
    ``(-inf, e0)``, the half-open interior bins ``[e_i, e_{i+1})``, and an
    overflow bin ``[e_last, inf)`` — so no sample is ever dropped."""

    name: str
    edges: Tuple[float, ...]
    doc: str = ""

    @property
    def num_bins(self) -> int:
        return len(self.edges) + 1


@dataclasses.dataclass(frozen=True)
class MetricRegistry:
    """A fixed set of metrics plus the pure update/merge/fetch algebra.

    Frozen and tuple-valued so instances are hashable — a registry is part
    of the jit / ``lru_cache`` keys of the compiled engines (two runs with
    different registries compile different programs; the same registry
    object reuses one executable).
    """

    counters: Tuple[Counter, ...] = ()
    gauges: Tuple[Gauge, ...] = ()
    histograms: Tuple[Histogram, ...] = ()

    # -- state ---------------------------------------------------------------

    def init_state(self) -> dict:
        """Zeroed accumulation pytree (device arrays once traced/put)."""
        return {
            "counters": {c.name: jnp.zeros((), jnp.float32)
                         for c in self.counters},
            "gauges": {g.name: jnp.zeros((), jnp.float32)
                       for g in self.gauges},
            "hist": {h.name: jnp.zeros((h.num_bins,), jnp.float32)
                     for h in self.histograms},
        }

    def _hist(self, name: str) -> Histogram:
        for h in self.histograms:
            if h.name == name:
                return h
        raise KeyError(f"unknown histogram {name!r}; known: "
                       f"{[h.name for h in self.histograms]}")

    # -- update (jnp-traceable) ----------------------------------------------

    def update(self, state: dict, counters: Optional[Mapping] = None,
               gauges: Optional[Mapping] = None,
               hists: Optional[Mapping] = None) -> dict:
        """One accumulation step — pure, traceable, shape-preserving.

        ``counters``: name -> scalar increment; ``gauges``: name -> new
        value; ``hists``: name -> (values, weights) arrays of equal shape
        (weights 0/1 masks keep the counts exactly integral).
        """
        new_c = dict(state["counters"])
        for name, inc in (counters or {}).items():
            new_c[name] = new_c[name] + jnp.asarray(inc, jnp.float32)
        new_g = dict(state["gauges"])
        for name, val in (gauges or {}).items():
            new_g[name] = jnp.asarray(val, jnp.float32)
        new_h = dict(state["hist"])
        for name, (values, weights) in (hists or {}).items():
            spec = self._hist(name)
            edges = jnp.asarray(spec.edges, jnp.float32)
            v = jnp.ravel(jnp.asarray(values)).astype(jnp.float32)
            w = jnp.ravel(jnp.asarray(weights)).astype(jnp.float32)
            idx = jnp.searchsorted(edges, v, side="right")
            # one-hot contraction, not scatter-add: a (S, B) matmul has a
            # fixed reduction order, and with 0/1 weights the bin counts
            # are integers — exact under any order (the parity contract)
            onehot = (idx[:, None] == jnp.arange(spec.num_bins)[None, :])
            new_h[name] = new_h[name] + w @ onehot.astype(jnp.float32)
        return {"counters": new_c, "gauges": new_g, "hist": new_h}

    # -- merge ---------------------------------------------------------------

    def merge(self, a: dict, b: dict) -> dict:
        """Combine two accumulation states (counters/hists add, gauges
        take the maximum — merge order must not matter)."""
        return {
            "counters": jax.tree.map(jnp.add, a["counters"], b["counters"]),
            "gauges": jax.tree.map(jnp.maximum, a["gauges"], b["gauges"]),
            "hist": jax.tree.map(jnp.add, a["hist"], b["hist"]),
        }

    def merge_stacked(self, state: dict, axis: int = 0) -> dict:
        """Collapse a leading batch axis (vmapped seeds, mesh shards)."""
        return {
            "counters": jax.tree.map(lambda l: jnp.sum(l, axis=axis),
                                     state["counters"]),
            "gauges": jax.tree.map(lambda l: jnp.max(l, axis=axis),
                                   state["gauges"]),
            "hist": jax.tree.map(lambda l: jnp.sum(l, axis=axis),
                                 state["hist"]),
        }

    # -- host side -----------------------------------------------------------

    def fetch(self, state: dict) -> dict:
        """Device state -> host snapshot (floats + np histogram arrays).
        The ONE host round-trip of a run."""
        return {
            "counters": {k: float(v) for k, v in state["counters"].items()},
            "gauges": {k: float(v) for k, v in state["gauges"].items()},
            "hist": {k: np.asarray(v) for k, v in state["hist"].items()},
        }

    def hist_stats(self, name: str, counts) -> dict:
        """Approximate count/mean/p50/p90 from binned counts (interior
        bins use their midpoint; under/overflow clamp to the edge)."""
        spec = self._hist(name)
        c = np.asarray(counts, np.float64)
        e = np.asarray(spec.edges, np.float64)
        rep = np.concatenate([[e[0]], (e[:-1] + e[1:]) / 2.0, [e[-1]]])
        total = float(c.sum())
        if total <= 0:
            return {"count": 0.0, "mean": float("nan"),
                    "p50": float("nan"), "p90": float("nan")}
        cdf = np.cumsum(c) / total
        return {
            "count": total,
            "mean": float((c * rep).sum() / total),
            "p50": float(rep[int(np.searchsorted(cdf, 0.5))]),
            "p90": float(rep[int(np.searchsorted(cdf, 0.9))]),
        }

    def summary(self, snapshot: dict) -> str:
        """Terminal summary table of a fetched snapshot."""
        lines = [f"{'metric':<22s} {'value':>14s}"]
        for c in self.counters:
            lines.append(f"{c.name:<22s} {snapshot['counters'][c.name]:>14.6g}")
        sc = snapshot["counters"]
        if "successes" in sc and "contacts" in sc:
            rate = sc["successes"] / max(sc["contacts"], 1.0)
            lines.append(f"{'success_rate':<22s} {rate:>14.4f}")
        for g in self.gauges:
            lines.append(f"{g.name:<22s} {snapshot['gauges'][g.name]:>14.6g}")
        lines.append(f"{'histogram':<22s} {'count':>10s} {'mean':>12s} "
                     f"{'p50':>12s} {'p90':>12s}")
        for h in self.histograms:
            st = self.hist_stats(h.name, snapshot["hist"][h.name])
            lines.append(f"{h.name:<22s} {st['count']:>10.0f} "
                         f"{st['mean']:>12.4g} {st['p50']:>12.4g} "
                         f"{st['p90']:>12.4g}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Composition: registry + per-device table + theory probes as ONE carry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetrySuite:
    """Composite telemetry carried through the engines as a single state.

    A suite bundles up to three accumulators — the global
    ``MetricRegistry``, a per-client ``perdevice.DeviceTable``, and
    ``probes.TheoryProbes`` — under the state keys ``"metrics"`` /
    ``"device"`` / ``"probes"``.  It quacks like a registry everywhere the
    engines care (``init_state`` / ``merge`` / ``merge_stacked`` /
    ``fetch`` / ``summary``, plus hashability so it keys the same jit /
    ``lru_cache`` entries), and ``record_round`` dispatches to
    ``TelemetrySuite.record`` — so the scan body, the loop's
    ``jit_record``, the pjit step, and the seed-vmap stacking in
    ``experiments/batch.py`` all work with a suite UNCHANGED.  The
    zero-mid-run-host-sync contract is inherited: every sub-state is a
    jnp pytree fetched once at run end.
    """

    metrics: Optional[MetricRegistry] = None
    device: Optional[object] = None  # perdevice.DeviceTable
    probes: Optional[object] = None  # probes.TheoryProbes

    def _parts(self):
        return [(k, a) for k, a in (("metrics", self.metrics),
                                    ("device", self.device),
                                    ("probes", self.probes))
                if a is not None]

    def init_state(self) -> dict:
        return {k: a.init_state() for k, a in self._parts()}

    def record(self, state: dict, metrics: Mapping, tau) -> dict:
        out = {}
        for k, a in self._parts():
            if k == "metrics":
                out[k] = record_round(a, state[k], metrics, tau)
            else:
                out[k] = a.update(state[k], metrics, tau)
        return out

    def merge(self, a: dict, b: dict) -> dict:
        return {k: acc.merge(a[k], b[k]) for k, acc in self._parts()}

    def merge_stacked(self, state: dict, axis: int = 0) -> dict:
        return {k: a.merge_stacked(state[k], axis=axis)
                for k, a in self._parts()}

    def fetch(self, state: dict) -> dict:
        return {k: a.fetch(state[k]) for k, a in self._parts()}

    def summary(self, snapshot: dict) -> str:
        parts = []
        if self.metrics is not None:
            parts.append(self.metrics.summary(snapshot["metrics"]))
        if self.device is not None:
            parts.append("per-device stragglers (fewest contacts first):")
            parts.append(self.device.summary(snapshot["device"]))
        if self.probes is not None:
            m = self.probes.measured(snapshot["probes"])
            parts.append(
                "probes (measured): "
                + "  ".join(f"{k}={v:.4g}" for k, v in m.items())
            )
        return "\n".join(parts)


# ---------------------------------------------------------------------------
# Host-side snapshot algebra (post-fetch / post-JSONL merging)
# ---------------------------------------------------------------------------


def _merge_fetched_registry(snaps) -> dict:
    out = {
        "counters": {k: 0.0 for k in snaps[0]["counters"]},
        "gauges": {k: -np.inf for k in snaps[0]["gauges"]},
        "hist": {k: np.zeros_like(np.asarray(v, np.float64))
                 for k, v in snaps[0]["hist"].items()},
    }
    for s in snaps:
        for k, v in s["counters"].items():
            out["counters"][k] += float(v)
        for k, v in s["gauges"].items():
            out["gauges"][k] = max(out["gauges"][k], float(v))
        for k, v in s["hist"].items():
            out["hist"][k] = out["hist"][k] + np.asarray(v, np.float64)
    return out


def merge_fetched(snapshots) -> dict:
    """Merge fetched (or JSONL-loaded) snapshots: counters/hists add,
    gauges max — the numpy mirror of ``MetricRegistry.merge``.  Suite
    snapshots (with ``"metrics"`` / ``"device"`` / ``"probes"`` sections)
    merge section-wise: device fields follow ``perdevice.FIELD_KIND``
    (sums add, maxima max), probe accumulators all add.
    """
    snaps = list(snapshots)
    if not snaps:
        raise ValueError("no snapshots to merge")
    if "counters" in snaps[0]:  # plain registry snapshot
        return _merge_fetched_registry(snaps)
    out: dict = {}
    if "metrics" in snaps[0]:
        out["metrics"] = _merge_fetched_registry(
            [s["metrics"] for s in snaps])
    if "device" in snaps[0]:
        from repro.telemetry.perdevice import FIELD_KIND

        dev = {}
        for f, kind in FIELD_KIND.items():
            if f not in snaps[0]["device"]:
                continue
            stack = np.stack([np.asarray(s["device"][f], np.float64)
                              for s in snaps])
            dev[f] = (np.sum if kind == "sum" else np.max)(stack, axis=0)
        dev["rounds"] = float(dev["rounds"])
        out["device"] = dev
    if "probes" in snaps[0]:
        out["probes"] = {
            f: float(sum(s["probes"][f] for s in snaps))
            for f in snaps[0]["probes"]
        }
    return out


def to_jsonable(snapshot: dict) -> dict:
    """Fetched snapshot -> plain lists/floats for the JSONL sink.  Suite
    snapshots serialise section-wise (same keys back out of
    ``read_jsonl`` + ``merge_fetched``)."""
    if "counters" not in snapshot:  # suite snapshot
        out: dict = {}
        if "metrics" in snapshot:
            out["metrics"] = to_jsonable(snapshot["metrics"])
        if "device" in snapshot:
            from repro.telemetry.perdevice import table_to_jsonable

            out["device"] = table_to_jsonable(snapshot["device"])
        if "probes" in snapshot:
            out["probes"] = {k: float(v)
                             for k, v in snapshot["probes"].items()}
        return out
    return {
        "counters": {k: float(v) for k, v in snapshot["counters"].items()},
        "gauges": {k: float(v) for k, v in snapshot["gauges"].items()},
        "hist": {k: [float(x) for x in np.asarray(v)]
                 for k, v in snapshot["hist"].items()},
    }


# ---------------------------------------------------------------------------
# The built-in AFL round registry
# ---------------------------------------------------------------------------

# fixed, model-independent edges: registries must hash equal across runs
# so every engine/seed shares one compiled program
_STALENESS_EDGES = (1., 2., 3., 4., 6., 8., 12., 16., 24., 32., 48., 64.,
                    96., 128.)
_TAU_EDGES = (0.5, 1., 2., 4., 8., 16., 32., 64., 128., 256.)
_BITS_EDGES = tuple(float(2 ** e) for e in range(10, 31, 2))
_K_EDGES = tuple(float(4 ** e) for e in range(0, 13))
_B_EDGES = (1., 2., 3., 4., 5., 6., 8., 10., 12., 16., 20., 24., 32.)


def afl_registry() -> MetricRegistry:
    """The built-in registry for Algorithm-1 rounds: the staleness /
    realized-bits / contact-duration / success / per-codec (k, b)
    distributions the paper's convergence story runs on."""
    return MetricRegistry(
        counters=(
            Counter("rounds", "rounds advanced"),
            Counter("contacts", "feasible contact events (zeta & energy)"),
            Counter("successes", "uploads that shipped >0 coordinates"),
            Counter("bits_total", "realized payload bits (<= tau*A budget)"),
            Counter("energy_total", "transmit energy spent (J)"),
        ),
        gauges=(
            Gauge("round", "last round index recorded"),
        ),
        histograms=(
            Histogram("staleness", _STALENESS_EDGES,
                      "delta_tau = r - kappa_n at contact"),
            Histogram("contact_tau", _TAU_EDGES,
                      "contact duration tau_n (s) at contact"),
            Histogram("bits", _BITS_EDGES,
                      "realized bits per successful upload"),
            Histogram("k", _K_EDGES,
                      "coordinates kept per successful upload"),
            Histogram("b", _B_EDGES,
                      "value bit-width on the wire (u or the codec's b*)"),
        ),
    )


#: Shared default instance — using the same object across engines keys one
#: compile-cache entry (MetricRegistry is hashable by value, so equal
#: registries hit the same cache either way).
AFL_REGISTRY = afl_registry()


def record_round(registry, state: dict, metrics: dict, tau) -> dict:
    """Fold one AFL round's metric dict into the accumulation state.

    Uses only the metric keys ALL three execution paths emit
    (``afl_round``, the scan body, and the distributed step):
    uploads/success/theta/bits/k/b/energy — so the same function is the
    telemetry stage of every engine and their states stay bit-comparable.
    ``tau`` is the round's (N,) contact-duration input.

    ``registry`` may also be a :class:`TelemetrySuite` (or anything with a
    ``record`` method): the call dispatches, which is how the per-device
    table and theory probes ride every engine without touching the
    scan-body / pjit-step / loop call sites.
    """
    if not isinstance(registry, MetricRegistry):
        return registry.record(state, metrics, tau)
    okf = metrics["uploads"]
    succ = metrics["success"]
    return registry.update(
        state,
        counters={
            "rounds": 1.0,
            "contacts": jnp.sum(okf),
            "successes": jnp.sum(succ),
            "bits_total": jnp.sum(metrics["bits"]),
            "energy_total": jnp.sum(metrics["energy"]),
        },
        gauges={"round": state["counters"]["rounds"] + 1.0},
        hists={
            "staleness": (metrics["theta"], okf),
            "contact_tau": (tau, okf),
            "bits": (metrics["bits"], succ),
            "k": (metrics["k"], succ),
            "b": (metrics["b"], succ),
        },
    )


# ---------------------------------------------------------------------------
# The streaming-ingest (serve-path) registry
# ---------------------------------------------------------------------------

_FILL_EDGES = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)


def serve_registry() -> MetricRegistry:
    """Registry for the streaming aggregation server (``repro/serve``).

    Device-side counters (``batches`` / ``ingested`` / ``bits_ingested`` /
    ``weight_sum`` and the histograms) accumulate inside the fused ingest
    op; the arrival-queue counters (``received`` / ``accepted`` /
    ``rejected`` / ``deferred``) and queue gauges live host-side in the
    :class:`repro.serve.queue.ArrivalBuffer` and are folded in when the
    server snapshots — one state, one fetch, same algebra as the engines.
    """
    return MetricRegistry(
        counters=(
            Counter("batches", "fused ingest batches executed"),
            Counter("ingested", "uploads aggregated into the global model"),
            Counter("bits_ingested", "wire bits decoded and aggregated"),
            Counter("weight_sum", "sum of alpha*s(delta_tau) mix weights"),
            Counter("received", "uploads offered to the arrival buffer"),
            Counter("accepted", "uploads admitted to the arrival buffer"),
            Counter("rejected", "uploads refused by backpressure (reject)"),
            Counter("deferred", "uploads pushed back by backpressure (defer)"),
        ),
        gauges=(
            Gauge("server_round", "aggregation rounds applied"),
            Gauge("queue_depth", "arrival-buffer depth at snapshot"),
            Gauge("queue_peak", "peak arrival-buffer depth"),
        ),
        histograms=(
            Histogram("staleness", _STALENESS_EDGES,
                      "delta_tau of ingested uploads"),
            Histogram("batch_fill", _FILL_EDGES,
                      "occupied fraction of each fused batch"),
            Histogram("bits", _BITS_EDGES,
                      "wire bits per ingested upload"),
        ),
    )


#: Shared default instance (same one-compile-cache-entry rationale as
#: :data:`AFL_REGISTRY`).
SERVE_REGISTRY = serve_registry()


def record_ingest(registry: MetricRegistry, state: dict, *, mask, dtau,
                  bits, weights) -> dict:
    """Fold one fused ingest batch into the serve registry state
    (jnp-traceable — called inside the jitted ingest op).  ``mask`` is the
    (B,) slot-occupancy/feasibility mask, ``weights`` the realized
    ``mask * alpha * s(dtau)`` mixing weights."""
    mask = jnp.asarray(mask, jnp.float32)
    return registry.update(
        state,
        counters={
            "batches": 1.0,
            "ingested": jnp.sum(mask),
            "bits_ingested": jnp.sum(jnp.asarray(bits, jnp.float32) * mask),
            "weight_sum": jnp.sum(jnp.asarray(weights, jnp.float32)),
        },
        gauges={"server_round": state["gauges"]["server_round"] + 1.0},
        hists={
            "staleness": (dtau, mask),
            "batch_fill": (jnp.mean(mask)[None], jnp.ones((1,), jnp.float32)),
            "bits": (bits, mask),
        },
    )


def record_het(telemetry, state: dict, het) -> dict:
    """Fold one round's heterogeneity loss masks into a telemetry state.

    ``het`` is a ``ScenarioProvider.aux_round`` dict — (N,) masks under
    "unavail" / "dropout" — or None.  Only a :class:`TelemetrySuite`
    carrying a per-device table has anywhere to put per-client loss
    counters, so everything else (plain registries, suites without a
    table, het=None) is an identity — which keeps every engine call site
    unconditional.
    """
    if (het is None or not isinstance(telemetry, TelemetrySuite)
            or telemetry.device is None):
        return state
    new = dict(state)
    new["device"] = telemetry.device.update_het(state["device"], het)
    return new


@lru_cache(maxsize=8)
def jit_record(registry: MetricRegistry):
    """Jitted ``record_round`` for the per-round loop engine (one compile
    per registry; the scan/pjit engines trace ``record_round`` inline)."""
    return jax.jit(
        lambda state, metrics, tau: record_round(registry, state, metrics,
                                                 tau)
    )
