"""Wall-clock span/phase tracing with async-dispatch-safe fencing.

JAX dispatches asynchronously: ``time.time()`` after a jitted call times
the *dispatch*, not the work, unless the result is fenced with
``jax.block_until_ready``.  ``PhaseTracer.span`` records honest wall-clock
phases (compile vs execute vs eval) when the caller fences inside the
span (``tracer.fence(out)``); repeated spans with the same name aggregate
in the summary, so per-round spans stay readable.

Optional profiler hooks: constructing the tracer with ``profile_dir``
(the ``--profile-dir`` flag of train/sweep/benchmarks) wraps each span in
``jax.profiler.TraceAnnotation`` and brackets the run with
``start_trace``/``stop_trace`` so spans line up with the device timeline
in TensorBoard/Perfetto.  Without ``profile_dir`` the tracer costs two
``perf_counter`` calls and a list append per span.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Optional

import jax


@dataclasses.dataclass
class Span:
    name: str
    start: float  # perf_counter seconds
    duration: float
    meta: dict
    parent: Optional[str] = None  # enclosing span's name (nesting)
    depth: int = 0  # nesting depth at entry (0 = top level)
    error: Optional[str] = None  # exception type name if the body raised


class PhaseTracer:
    """Collects named wall-clock spans; optionally mirrors them into the
    JAX profiler when ``profile_dir`` is set."""

    def __init__(self, profile_dir: Optional[str] = None):
        self.profile_dir = profile_dir or None
        self.spans: list[Span] = []
        self._tracing = False
        self._stack: list[str] = []  # open span names (nesting)

    # -- spans ---------------------------------------------------------------

    @contextmanager
    def span(self, name: str, **meta):
        """Record a wall-clock span.  Exception-safe: a body that raises
        still lands its span (with the exception type under ``error``),
        so a crashed sweep's trace shows WHERE the time went before the
        failure.  Spans nest — an inner span records its enclosing span
        as ``parent`` and its ``depth``, surfaced by ``events()``."""
        ann = None
        if self.profile_dir is not None:
            try:
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:  # pragma: no cover - profiler backend-dependent
                ann = None
        parent = self._stack[-1] if self._stack else None
        depth = len(self._stack)
        self._stack.append(name)
        err: Optional[str] = None
        t0 = time.perf_counter()
        try:
            yield self
        except BaseException as e:
            err = type(e).__name__
            raise
        finally:
            self._stack.pop()
            self.spans.append(
                Span(name, t0, time.perf_counter() - t0, dict(meta),
                     parent=parent, depth=depth, error=err)
            )
            if ann is not None:
                ann.__exit__(None, None, None)

    @staticmethod
    def fence(x):
        """Block until ``x``'s arrays are computed (no-op on host data) —
        call before leaving a span so its wall time covers the work."""
        try:
            jax.block_until_ready(x)
        except Exception:  # non-array pytrees / already-deleted buffers
            pass
        return x

    # -- profiler bracket ----------------------------------------------------

    def start(self) -> None:
        """Begin a device trace under ``profile_dir`` (no-op without)."""
        if self.profile_dir is None or self._tracing:
            return
        try:
            jax.profiler.start_trace(self.profile_dir)
            self._tracing = True
        except Exception:  # pragma: no cover - profiler backend-dependent
            self.profile_dir = None

    def stop(self) -> None:
        if self._tracing:
            try:
                jax.profiler.stop_trace()
            finally:
                self._tracing = False

    # -- reporting -----------------------------------------------------------

    def totals(self) -> dict:
        """name -> {count, total_s, max_s} aggregated over spans."""
        out: dict = {}
        for s in self.spans:
            agg = out.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += s.duration
            agg["max_s"] = max(agg["max_s"], s.duration)
        return out

    def summary(self) -> str:
        lines = [f"{'phase':<24s} {'count':>6s} {'total_s':>10s} "
                 f"{'mean_ms':>10s} {'max_ms':>10s}"]
        for name, agg in self.totals().items():
            lines.append(
                f"{name:<24s} {agg['count']:>6d} {agg['total_s']:>10.3f} "
                f"{agg['total_s'] / agg['count'] * 1e3:>10.2f} "
                f"{agg['max_s'] * 1e3:>10.2f}"
            )
        return "\n".join(lines)

    def events(self) -> list[dict]:
        """Span records for the JSONL sink (parent/depth attribute nested
        spans; ``error`` marks spans whose body raised)."""
        out = []
        for s in self.spans:
            ev = {"kind": "span", "name": s.name,
                  "start_s": round(s.start, 6),
                  "duration_s": round(s.duration, 6), **s.meta}
            if s.parent is not None:
                ev["parent"] = s.parent
                ev["depth"] = s.depth
            if s.error is not None:
                ev["error"] = s.error
            out.append(ev)
        return out
