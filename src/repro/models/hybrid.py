"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block
applied every ``cfg.attn_every`` layers [arXiv:2411.15242].

The backbone is split into segments of ``attn_every`` mamba layers; the
shared attention block (one weight copy) runs before every segment except
the first.  Because the shared block sees *different activations* at each
depth, decode keeps a separate KV-cache slot per invocation
(``n_attn = (num_layers - 1) // attn_every`` slots), while weights stay
shared — faithful to Zamba2's parameter-sharing trick.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.transformer import stack_specs
from repro.sharding.rules import ParamSpec


def n_attn_calls(cfg) -> int:
    return max((cfg.num_layers - 1) // cfg.attn_every, 1)


def segments(cfg):
    """Layer counts per segment: [attn_every, attn_every, ..., remainder]."""
    sizes, left = [], cfg.num_layers
    while left > 0:
        take = min(cfg.attn_every, left)
        sizes.append(take)
        left -= take
    return sizes


def param_specs(cfg) -> dict:
    return {
        "embed": L.embed_specs(cfg),
        "layers": stack_specs(M2.block_specs(cfg), cfg.num_layers),
        "shared_attn": {
            "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attn_specs(cfg),
        },
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "unembed": {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="small")
        },
    }


def _shared_attn(params, cfg, x, cos, sin):
    sp = params["shared_attn"]
    h = L.rms_norm(x, sp["ln"], cfg.norm_eps)
    q, k, v = L.attn_qkv(sp["attn"], cfg, h)
    q, k = L.apply_rope(q, k, cos, sin)
    attn = L.causal_attention(q, k, v)
    return x + L.attn_out(sp["attn"], attn, x.dtype)


def _mamba_scan(cfg, layer_params, x):
    def body(carry, lp):
        x = carry
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, _ = M2.mamba_block(lp["mamba"], cfg, h)
        return x + y, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, layer_params)
    return x


def forward(params, cfg, tokens, **_):
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)

    off = 0
    for i, size in enumerate(segments(cfg)):
        if i > 0:
            x = _shared_attn(params, cfg, x, cos, sin)
        seg = jax.tree.map(lambda a: a[off : off + size], params["layers"])
        x = _mamba_scan(cfg, seg, x)
        off += size
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"])


def init_cache(cfg, batch: int, max_seq: int):
    hd = cfg.resolved_head_dim
    na = len(segments(cfg)) - 1
    dt = cfg.activation_dtype
    c = M2.init_cache(cfg, batch)
    c["attn_k"] = jnp.zeros((na, batch, max_seq, cfg.num_kv_heads, hd), dt)
    c["attn_v"] = jnp.zeros((na, batch, max_seq, cfg.num_kv_heads, hd), dt)
    c["pos"] = jnp.full((batch, max_seq), -1, jnp.int32)
    return c


def cache_axes(cfg):
    ax = M2.cache_axes(cfg)
    ax["attn_k"] = ("layers", "batch", "seq", "kv_heads", "head_dim")
    ax["attn_v"] = ("layers", "batch", "seq", "kv_heads", "head_dim")
    ax["pos"] = ("batch", "seq")
    return ax


def prefill(params, cfg, tokens, *, max_seq=None, **_):
    """Run the prompt: returns (last logits, recurrent + shared-attn cache)."""
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)
    b, s = tokens.shape
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = L.rope_cos_sin(positions, cfg.resolved_head_dim, cfg.rope_theta)

    ak, av = [], []
    conv = {k: [] for k in ("conv_x", "conv_B", "conv_C")}
    ssm_all = []
    off = 0
    for i, size in enumerate(segments(cfg)):
        if i > 0:
            sp = params["shared_attn"]
            h = L.rms_norm(x, sp["ln"], cfg.norm_eps)
            q, k, v = L.attn_qkv(sp["attn"], cfg, h)
            q, k = L.apply_rope(q, k, cos, sin)
            attn = L.causal_attention(q, k, v)
            x = x + L.attn_out(sp["attn"], attn, x.dtype)
            ak.append(k)
            av.append(v)

        def body(carry, lp):
            x = carry
            h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
            y, cvs, st = M2.mamba_block(lp["mamba"], cfg, h, collect_cache=True)
            return x + y, (cvs["x"], cvs["B"], cvs["C"], st)

        seg = jax.tree.map(lambda a: a[off : off + size], params["layers"])
        x, (cx, cb, cc, st) = jax.lax.scan(body, x, seg)
        conv["conv_x"].append(cx)
        conv["conv_B"].append(cb)
        conv["conv_C"].append(cc)
        ssm_all.append(st)
        off += size

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]["w"].astype(x.dtype))
    pad = ((0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0))
    attn_k = jnp.pad(jnp.stack(ak, 0), pad) if ak else jnp.zeros(
        (0, b, max_seq, cfg.num_kv_heads, cfg.resolved_head_dim), x.dtype
    )
    attn_v = jnp.pad(jnp.stack(av, 0), pad) if av else jnp.zeros_like(attn_k)
    pos_arr = jnp.where(jnp.arange(max_seq)[None] < s, jnp.arange(max_seq)[None], -1)
    cache = {
        "conv_x": jnp.concatenate(conv["conv_x"], 0),
        "conv_B": jnp.concatenate(conv["conv_B"], 0),
        "conv_C": jnp.concatenate(conv["conv_C"], 0),
        "ssm": jnp.concatenate(ssm_all, 0),
        "attn_k": attn_k,
        "attn_v": attn_v,
        "pos": jnp.broadcast_to(pos_arr, (b, max_seq)).astype(jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg, cache, token, pos):
    x = params["embed"]["tok"][token][:, None, :].astype(cfg.activation_dtype)
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    s_cache = cache["attn_k"].shape[2]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    cos, sin = L.rope_cos_sin(posb, hd, cfg.rope_theta)
    slot = (pos % s_cache).astype(jnp.int32) if hasattr(pos, "astype") else pos % s_cache
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1)), (0, slot)
    )

    new_ak, new_av = [], []
    new_conv = {k: [] for k in ("conv_x", "conv_B", "conv_C")}
    new_ssm = []
    off = 0
    for i, size in enumerate(segments(cfg)):
        if i > 0:
            sp = params["shared_attn"]
            h = L.rms_norm(x, sp["ln"], cfg.norm_eps)
            q, k, v = L.attn_qkv(sp["attn"], cfg, h)
            q, k = L.apply_rope(q, k, cos, sin)
            ak = jax.lax.dynamic_update_slice(
                cache["attn_k"][i - 1], k.astype(cache["attn_k"].dtype), (0, slot, 0, 0)
            )
            av = jax.lax.dynamic_update_slice(
                cache["attn_v"][i - 1], v.astype(cache["attn_v"].dtype), (0, slot, 0, 0)
            )
            attn = L.decode_attention(q[:, 0], ak, av, length=jnp.minimum(pos + 1, s_cache),
                                      window_pos=new_pos)
            x = x + L.attn_out(sp["attn"], attn[:, None], x.dtype)
            new_ak.append(ak)
            new_av.append(av)

        def body(carry, xs):
            x = carry
            lp, cx, cb, cc, ssm = xs
            h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
            y, nc, ns = M2.mamba_block(
                lp["mamba"], cfg, h, conv_state={"x": cx, "B": cb, "C": cc}, ssm_state=ssm
            )
            return x + y, (nc["x"], nc["B"], nc["C"], ns)

        seg = jax.tree.map(lambda a: a[off : off + size], params["layers"])
        segc = [cache[k][off : off + size] for k in ("conv_x", "conv_B", "conv_C")]
        x, (cx, cb, cc, ssm) = jax.lax.scan(
            body, x, (seg, segc[0], segc[1], segc[2], cache["ssm"][off : off + size])
        )
        new_conv["conv_x"].append(cx)
        new_conv["conv_B"].append(cb)
        new_conv["conv_C"].append(cc)
        new_ssm.append(ssm)
        off += size

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))[:, 0]
    new_cache = {
        "conv_x": jnp.concatenate(new_conv["conv_x"], 0),
        "conv_B": jnp.concatenate(new_conv["conv_B"], 0),
        "conv_C": jnp.concatenate(new_conv["conv_C"], 0),
        "ssm": jnp.concatenate(new_ssm, 0),
        "attn_k": jnp.stack(new_ak, 0) if new_ak else cache["attn_k"],
        "attn_v": jnp.stack(new_av, 0) if new_av else cache["attn_v"],
        "pos": new_pos,
    }
    return logits, new_cache
