"""ResNet-9 for CIFAR-10 — the paper's own image model (§VI, 6.57M params).

conv(3->w) / conv(w->2w)+pool / residual(2w) / conv(2w->4w)+pool /
conv(4w->8w)+pool / residual(8w) / global-max-pool / FC.
BatchNorm uses in-batch statistics in both train and eval (no running
stats) — standard practice in non-iid FL where per-device running stats
diverge; noted in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec


def _conv_bn_specs(cin, cout):
    return {
        "w": ParamSpec((3, 3, cin, cout), (None, None, None, "mlp")),
        "scale": ParamSpec((cout,), ("mlp",), init="ones"),
        "bias": ParamSpec((cout,), ("mlp",), init="zeros"),
    }


def param_specs(cfg) -> dict:
    w = cfg.d_model  # base width (64)
    return {
        "c1": _conv_bn_specs(3, w),
        "c2": _conv_bn_specs(w, 2 * w),
        "r1a": _conv_bn_specs(2 * w, 2 * w),
        "r1b": _conv_bn_specs(2 * w, 2 * w),
        "c3": _conv_bn_specs(2 * w, 4 * w),
        "c4": _conv_bn_specs(4 * w, 8 * w),
        "r2a": _conv_bn_specs(8 * w, 8 * w),
        "r2b": _conv_bn_specs(8 * w, 8 * w),
        "fc": {
            "w": ParamSpec((8 * w, cfg.vocab_size), ("mlp", None), init="small"),
            "b": ParamSpec((cfg.vocab_size,), (None,), init="zeros"),
        },
    }


def _conv_bn(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    mu = jnp.mean(y, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(y, axis=(0, 1, 2), keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["scale"] + p["bias"]
    return jax.nn.relu(y)


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(params, cfg, images, **_):
    """images: (B, 32, 32, 3) float32 -> logits (B, classes)."""
    x = images.astype(jnp.float32)
    x = _conv_bn(params["c1"], x)
    x = _pool(_conv_bn(params["c2"], x))
    x = x + _conv_bn(params["r1b"], _conv_bn(params["r1a"], x))
    x = _pool(_conv_bn(params["c3"], x))
    x = _pool(_conv_bn(params["c4"], x))
    x = x + _conv_bn(params["r2b"], _conv_bn(params["r2a"], x))
    x = jnp.max(x, axis=(1, 2))  # global max pool
    return x @ params["fc"]["w"] + params["fc"]["b"], jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["images"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return -jnp.mean(ll)


def accuracy(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
