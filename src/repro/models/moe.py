"""Mixture-of-Experts layer (GShard-style grouped capacity dispatch).

Qwen-family MoE: optional shared experts (always-on dense path) + routed
experts with top-k softmax gating.  Dispatch uses one-hot einsums over
(group, token, expert, capacity) so GSPMD lowers the expert-parallel
exchange to all-to-all style collectives; tokens are processed in groups of
``GROUP`` to keep the dispatch tensors bounded.

FLOPs scale with *activated* experts (capacity ~= tokens * top_k * cf), not
with the full expert count — matching the MoE roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec

GROUP = 512  # tokens per dispatch group


def moe_specs(cfg) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    edt = "int8" if cfg.expert_dtype == "int8" else None
    sp = {
        "router": ParamSpec((d, e), ("embed", "experts"), init="small"),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=edt),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype=edt),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"), dtype=edt),
    }
    if edt:
        # per-expert dequantisation scales (applied to einsum OUTPUTS so the
        # int8 weights never materialise in bf16)
        for nm, fan in (("s_gate", d), ("s_up", d), ("s_down", f)):
            sp[nm] = ParamSpec((e,), ("experts",), init="const",
                               scale=(1.0 / fan) ** 0.5 / 48.0, dtype="float32")
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        sp["shared"] = {
            "wi_gate": ParamSpec((d, fs), ("embed", "mlp")),
            "wi_up": ParamSpec((d, fs), ("embed", "mlp")),
            "wo": ParamSpec((fs, d), ("mlp", "embed")),
            "gate": ParamSpec((d, 1), ("embed", None), init="small"),
        }
    return sp


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.num_experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(c, 1)


def route(logits, cfg):
    """Top-k routing. logits: (..., E). Returns (weights, mask) of (..., E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    mask = jax.nn.one_hot(topi, cfg.num_experts, dtype=jnp.float32).sum(-2)  # (...,E)
    weights = probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, mask


def load_balance_loss(probs_mean, dispatch_frac, num_experts: int):
    """Switch/GShard auxiliary loss: E * sum_e f_e * P_e."""
    return num_experts * jnp.sum(probs_mean * dispatch_frac)


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (B, S, d), aux_loss (scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    g = max(min(GROUP, t), 1)
    if t % g:  # pad tokens to a whole number of groups
        pad = g - t % g
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    ng = xt.shape[0] // g
    xg = xt.reshape(ng, g, d)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype))
    weights, mask = route(logits, cfg)  # (ng,g,E) f32

    cap = _capacity(g, cfg)
    # position of each token within its expert's buffer
    pos_in_exp = (jnp.cumsum(mask, axis=1) - 1.0) * mask  # (ng,g,E)
    keep = (pos_in_exp < cap).astype(jnp.float32) * mask
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    aux = load_balance_loss(
        probs.mean(axis=(0, 1)), mask.mean(axis=(0, 1)), cfg.num_experts
    )

    pos_oh = jax.nn.one_hot(pos_in_exp.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = keep[..., None] * pos_oh  # (ng,g,E,C)
    combine = (weights * keep)[..., None] * pos_oh  # (ng,g,E,C)

    dt = x.dtype
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg)  # (ng,E,C,d)
    gate = jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", xe, p["wi_up"].astype(dt))
    if cfg.expert_dtype == "int8":
        gate = gate * p["s_gate"][None, :, None, None].astype(dt)
        up = up * p["s_up"][None, :, None, None].astype(dt)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    if cfg.expert_dtype == "int8":
        ye = ye * p["s_down"][None, :, None, None].astype(dt)
    yg = jnp.einsum("gtec,gecd->gtd", combine.astype(dt), ye)  # (ng,g,d)

    y = yg.reshape(-1, d)[:t].reshape(b, s, d)

    if cfg.num_shared_experts:
        sp = p["shared"]
        gsh = jnp.einsum("bsd,df->bsf", x, sp["wi_gate"].astype(dt))
        ush = jnp.einsum("bsd,df->bsf", x, sp["wi_up"].astype(dt))
        hsh = jax.nn.silu(gsh.astype(jnp.float32)).astype(dt) * ush
        ysh = jnp.einsum("bsf,fd->bsd", hsh, sp["wo"].astype(dt))
        sgate = jax.nn.sigmoid(
            jnp.einsum("bsd,dk->bsk", x, sp["gate"].astype(dt)).astype(jnp.float32)
        ).astype(dt)
        y = y + sgate * ysh
    return y, aux
