"""Whisper-style encoder-decoder transformer [arXiv:2212.04356].

The mel-spectrogram + two-conv frontend is a STUB per the assignment:
``frames`` (B, encoder_seq, d_model) arrive as precomputed frame embeddings.
Encoder: bidirectional self-attention with sinusoidal positions.
Decoder: causal self-attention (KV cache) + cross-attention to the encoder
output (cross K/V precomputed once at prefill) + GELU MLP.
Pre-LN LayerNorm throughout (whisper uses LN, not RMSNorm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.transformer import stack_specs
from repro.sharding.rules import ParamSpec


def _ln_specs(cfg):
    return {
        "scale": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "bias": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }


def _gelu_mlp_specs(cfg):
    return {
        "wi": ParamSpec((cfg.d_model, cfg.d_ff), ("embed", "mlp")),
        "bi": ParamSpec((cfg.d_ff,), ("mlp",), init="zeros"),
        "wo": ParamSpec((cfg.d_ff, cfg.d_model), ("mlp", "embed")),
        "bo": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
    }


def _gelu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)) + p["bi"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype)) + p["bo"].astype(x.dtype)


def _enc_block_specs(cfg):
    return {
        "ln_attn": _ln_specs(cfg),
        "attn": L.attn_specs(cfg),
        "ln_mlp": _ln_specs(cfg),
        "mlp": _gelu_mlp_specs(cfg),
    }


def _dec_block_specs(cfg):
    return {
        "ln_self": _ln_specs(cfg),
        "self_attn": L.attn_specs(cfg),
        "ln_cross": _ln_specs(cfg),
        "cross_attn": L.attn_specs(cfg),
        "ln_mlp": _ln_specs(cfg),
        "mlp": _gelu_mlp_specs(cfg),
    }


def param_specs(cfg) -> dict:
    return {
        "embed": L.embed_specs(cfg),
        "enc_layers": stack_specs(_enc_block_specs(cfg), cfg.encoder_layers),
        "enc_ln_f": _ln_specs(cfg),
        "dec_layers": stack_specs(_dec_block_specs(cfg), cfg.num_layers),
        "dec_ln_f": _ln_specs(cfg),
        "unembed": {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="small")
        },
    }


def _sinusoid(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln(p, x, eps):
    return L.layer_norm(x, p["scale"], p["bias"], eps)


def encode(params, cfg, frames):
    """frames: (B, encoder_seq, d_model) stub embeddings -> encoder output."""
    x = frames.astype(cfg.activation_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(carry, lp):
        x = carry
        h = _ln(lp["ln_attn"], x, cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h)
        attn = L.causal_attention(q, k, v, causal=False)
        x = x + L.attn_out(lp["attn"], attn, x.dtype)
        h = _ln(lp["ln_mlp"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["enc_ln_f"], x, cfg.norm_eps)


def decode_full(params, cfg, tokens, enc_out):
    """Teacher-forced decoder pass (training). tokens: (B, S)."""
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def body(carry, lp):
        x = carry
        h = _ln(lp["ln_self"], x, cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h)
        attn = L.causal_attention(q, k, v)
        x = x + L.attn_out(lp["self_attn"], attn, x.dtype)
        h = _ln(lp["ln_cross"], x, cfg.norm_eps)
        q2, _, _ = L.attn_qkv(lp["cross_attn"], cfg, h)
        k2 = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"].astype(x.dtype))
        v2 = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"].astype(x.dtype))
        if cfg.qkv_bias:
            k2 = k2 + lp["cross_attn"]["bk"].astype(x.dtype)
            v2 = v2 + lp["cross_attn"]["bv"].astype(x.dtype)
        xatt = L.causal_attention(q2, k2, v2, causal=False)
        x = x + L.attn_out(lp["cross_attn"], xatt, x.dtype)
        h = _ln(lp["ln_mlp"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_ln_f"], x, cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))


def forward(params, cfg, tokens, *, frames=None, **_):
    enc = encode(params, cfg, frames)
    return decode_full(params, cfg, tokens, enc), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["tokens"], frames=batch["frames"])
    return L.cross_entropy(logits, batch["labels"])


def init_cache(cfg, batch: int, max_seq: int):
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    lcount = cfg.num_layers
    return {
        "k": jnp.zeros((lcount, batch, max_seq, cfg.num_kv_heads, hd), dt),
        "v": jnp.zeros((lcount, batch, max_seq, cfg.num_kv_heads, hd), dt),
        # cross-attention K/V, precomputed from the encoder output at prefill
        "xk": jnp.zeros((lcount, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dt),
        "xv": jnp.zeros((lcount, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dt),
        "pos": jnp.full((batch, max_seq), -1, jnp.int32),
    }


def cache_axes(cfg):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": kv, "v": kv,
            "xk": ("layers", "batch", "pos", "kv_heads", "head_dim"),
            "xv": ("layers", "batch", "pos", "kv_heads", "head_dim"),
            "pos": ("batch", "seq")}


def precompute_cross_kv(params, cfg, enc_out):
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"].astype(enc_out.dtype))
        if cfg.qkv_bias:
            k = k + lp["cross_attn"]["bk"].astype(enc_out.dtype)
            v = v + lp["cross_attn"]["bv"].astype(enc_out.dtype)
        return k, v

    ks, vs = jax.lax.map(one, params["dec_layers"])
    return ks, vs


def prefill(params, cfg, tokens, *, frames=None, max_seq=None, **_):
    """Encoder + teacher-forced decoder prompt pass; returns (logits, cache)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    max_seq = max_seq or s
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)
    x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)[None]

    def body(carry, lp):
        x = carry
        h = _ln(lp["ln_self"], x, cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h)
        attn = L.causal_attention(q, k, v)
        x = x + L.attn_out(lp["self_attn"], attn, x.dtype)
        h = _ln(lp["ln_cross"], x, cfg.norm_eps)
        q2, _, _ = L.attn_qkv(lp["cross_attn"], cfg, h)
        k2 = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"].astype(x.dtype))
        v2 = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"].astype(x.dtype))
        if cfg.qkv_bias:
            k2 = k2 + lp["cross_attn"]["bk"].astype(x.dtype)
            v2 = v2 + lp["cross_attn"]["bv"].astype(x.dtype)
        xatt = L.causal_attention(q2, k2, v2, causal=False)
        x = x + L.attn_out(lp["cross_attn"], xatt, x.dtype)
        h = _ln(lp["ln_mlp"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), (k, v, k2, v2)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = _ln(params["dec_ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]["w"].astype(x.dtype))
    pad = ((0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0))
    pos_arr = jnp.where(jnp.arange(max_seq)[None] < s, jnp.arange(max_seq)[None], -1)
    cache = {
        "k": jnp.pad(ks, pad), "v": jnp.pad(vs, pad), "xk": xks, "xv": xvs,
        "pos": jnp.broadcast_to(pos_arr, (b, max_seq)).astype(jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg, cache, token, pos):
    x = params["embed"]["tok"][token][:, None, :].astype(cfg.activation_dtype)
    b = x.shape[0]
    s_cache = cache["k"].shape[2]
    d = cfg.d_model
    posf = jnp.asarray(pos, jnp.int32)
    pe = _sinusoid(s_cache, d)[jnp.minimum(posf, s_cache - 1)]
    x = x + pe[None, None].reshape(1, 1, d).astype(x.dtype)
    slot = posf % s_cache
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.broadcast_to(posf, (b, 1)), (0, slot)
    )

    def body(carry, xs):
        x = carry
        lp, kc, vc, xk, xv = xs
        h = _ln(lp["ln_self"], x, cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["self_attn"], cfg, h)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
        attn = L.decode_attention(q[:, 0], kc, vc, length=jnp.minimum(pos + 1, s_cache),
                                  window_pos=new_pos)
        x = x + L.attn_out(lp["self_attn"], attn[:, None], x.dtype)
        h = _ln(lp["ln_cross"], x, cfg.norm_eps)
        q2, _, _ = L.attn_qkv(lp["cross_attn"], cfg, h)
        xatt = L.decode_attention(q2[:, 0], xk, xv, length=xk.shape[1])
        x = x + L.attn_out(lp["cross_attn"], xatt[:, None], x.dtype)
        h = _ln(lp["ln_mlp"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = _ln(params["dec_ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))[:, 0]
    new_cache = dict(cache, k=ks, v=vs, pos=new_pos)
    return logits, new_cache
