"""Qwen2-VL language backbone [arXiv:2409.12191].

Per the assignment carve-out, the ViT/merger vision frontend is a STUB:
``vision_embeds`` (B, n_img, d_model) arrive precomputed, and are spliced in
front of the text-token embeddings.  M-RoPE 3D positions: image patches get
(t=0, h=row, w=col); text tokens continue temporally after the image with
h == w == t (dynamic-resolution details reduce to the provided grid).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import transformer as T

param_specs = T.param_specs
init_cache = T.init_cache
decode_step = T.decode_step
prefill = T.prefill


def mrope_positions(batch: int, n_img: int, n_text: int, grid: int):
    """(3, B, n_img + n_text) position ids for an image-then-text stream."""
    rows = jnp.arange(n_img) // max(grid, 1)
    cols = jnp.arange(n_img) % max(grid, 1)
    t_img = jnp.zeros(n_img, jnp.int32)
    start = (max(grid, 1) if n_img else 0)
    t_text = start + jnp.arange(n_text)
    t = jnp.concatenate([t_img, t_text])
    h = jnp.concatenate([rows, t_text])
    w = jnp.concatenate([cols, t_text])
    pos = jnp.stack([t, h, w]).astype(jnp.int32)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, n_img + n_text))


def forward(params, cfg, tokens, *, vision_embeds=None, positions=None, **kw):
    if vision_embeds is not None:
        emb = params["embed"]["tok"]
        text = emb[tokens].astype(cfg.activation_dtype)
        x = jnp.concatenate([vision_embeds.astype(cfg.activation_dtype), text], axis=1)
        b, n_img = vision_embeds.shape[:2]
        grid = int(max(n_img, 1) ** 0.5) or 1
        if positions is None:
            positions = mrope_positions(b, n_img, tokens.shape[1], grid)
        return T.forward(params, cfg, embeds=x, positions=positions, **kw)
    return T.forward(params, cfg, tokens, positions=positions, **kw)


def loss_fn(params, cfg, batch):
    """Cross-entropy on the text positions only (vision positions unlabeled)."""
    from repro.models import layers as L

    logits, aux = forward(
        params, cfg, batch["tokens"], vision_embeds=batch.get("vision_embeds")
    )
    n_img = batch["vision_embeds"].shape[1] if "vision_embeds" in batch else 0
    text_logits = logits[:, n_img:]
    return L.cross_entropy(text_logits, batch["labels"]) + cfg.router_aux_loss * aux
