"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Layers are stacked on a leading ``layers`` axis and executed with
``lax.scan`` so HLO size (and CPU compile time at 512-way GSPMD) stays
bounded for 80-layer configs.  Covers:

* dense GQA blocks (llama3 / internlm2 / qwen2 / qwen3 signatures:
  qkv-bias, qk-norm, GQA, tied embeddings),
* MoE blocks (shared + routed experts, top-k routing, capacity dispatch) —
  see ``repro/models/moe.py``,
* the Qwen2-VL language backbone: M-RoPE position streams and an embedding
  injection path for the (stubbed) vision frontend.

Decode supports a plain KV cache (``decode_32k``) and a ring-buffer
sliding-window cache which bounds state for ``long_500k``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.sharding.rules import ParamSpec


def stack_specs(specs, n: int, axis_name: str = "layers"):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.dims, s.init, s.scale, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def block_specs(cfg) -> dict:
    sp = {
        "ln_attn": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "ln_mlp": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "attn": L.attn_specs(cfg),
    }
    if cfg.is_moe:
        sp["moe"] = MOE.moe_specs(cfg)
    else:
        sp["mlp"] = L.mlp_specs(cfg)
    return sp


def param_specs(cfg) -> dict:
    sp = {
        "embed": L.embed_specs(cfg),
        "layers": stack_specs(block_specs(cfg), cfg.num_layers),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        sp["unembed"] = {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="small")
        }
    return sp


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


def _block(cfg, p, x, cos, sin, sliding_window: int):
    h = L.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], cfg, h)
    q, k = L.apply_rope(q, k, cos, sin)
    attn = L.causal_attention(q, k, v, sliding_window=sliding_window)
    x = x + L.attn_out(p["attn"], attn, x.dtype)
    h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = MOE.moe_apply(p["moe"], cfg, h)
    else:
        y, aux = L.mlp_apply(p["mlp"], h), jnp.zeros((), jnp.float32)
    return x + y, aux


def forward(params, cfg, tokens=None, *, embeds=None, positions=None, collect_kv=False):
    """Returns (logits, aux_loss) — and the KV cache too if ``collect_kv``.

    ``embeds`` (B,S,d) overrides token embedding (VLM/audio stub injection).
    ``positions``: (B,S) or (3,B,S) for M-RoPE; defaults to arange.
    """
    if embeds is None:
        emb = params["embed"]["tok"]
        x = emb[tokens].astype(cfg.activation_dtype)
    else:
        x = embeds.astype(cfg.activation_dtype)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = (
            L.text_mrope_positions(b, s)
            if cfg.mrope_sections
            else jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        )
    cos, sin = L.rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)

    def body(carry, lp):
        x, aux = carry
        h = L.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h)
        q, k = L.apply_rope(q, k, cos, sin)
        attn = L.causal_attention(q, k, v, sliding_window=cfg.sliding_window)
        x = x + L.attn_out(lp["attn"], attn, x.dtype)
        h2 = L.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        if cfg.is_moe:
            y, a = MOE.moe_apply(lp["moe"], cfg, h2)
        else:
            y, a = L.mlp_apply(lp["mlp"], h2), jnp.zeros((), jnp.float32)
        ys = (k, v) if collect_kv else None
        return (x + y, aux + a), ys

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, cfg, x)
    if collect_kv:
        return logits, aux, kvs
    return logits, aux


def loss_fn(params, cfg, batch):
    """Mean next-token cross-entropy + MoE aux. batch: tokens/labels (B,S)."""
    logits, aux = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"]) + cfg.router_aux_loss * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def cache_specs(cfg, batch: int, max_seq: int) -> dict:
    """ShapeDtypeStructs + logical dims for the KV cache."""
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    dims = ("layers", "batch", "seq", "kv_heads", "head_dim")
    dt = cfg.activation_dtype
    specs = {
        "k": ParamSpec(shape, dims, init="zeros", dtype=str(dt)),
        "v": ParamSpec(shape, dims, init="zeros", dtype=str(dt)),
        "pos": ParamSpec((batch, max_seq), ("batch", "seq"), init="zeros", dtype="int32"),
        "length": ParamSpec((), (), init="zeros", dtype="int32"),
    }
    return specs


def init_cache(cfg, batch: int, max_seq: int):
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, hd)
    cache = {
        "pos": jnp.full((batch, max_seq), -1, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros(shape, jnp.int8)
        cache["v"] = jnp.zeros(shape, jnp.int8)
        sshape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads)
        cache["k_scale"] = jnp.zeros(sshape, jnp.float32)
        cache["v_scale"] = jnp.zeros(sshape, jnp.float32)
    else:
        cache["k"] = jnp.zeros(shape, dt)
        cache["v"] = jnp.zeros(shape, dt)
    return cache


def prefill(params, cfg, tokens, *, embeds=None, positions=None, max_seq: Optional[int] = None):
    """Run the prompt, return (last-token logits, filled cache)."""
    logits, aux, (ks, vs) = forward(
        params, cfg, tokens, embeds=embeds, positions=positions, collect_kv=True
    )
    b, s = (tokens.shape if embeds is None else embeds.shape[:2])
    max_seq = max_seq or s
    k = ks
    v = vs
    pos = jnp.where(
        jnp.arange(max_seq)[None] < s, jnp.arange(max_seq)[None], -1
    ) * jnp.ones((b, 1), jnp.int32)
    cache = {"pos": pos, "length": jnp.asarray(s, jnp.int32)}
    padw = ((0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0))
    if cfg.kv_cache_dtype == "int8":
        kq, ksc = jax.vmap(L.quantize_kv)(k)
        vq, vsc = jax.vmap(L.quantize_kv)(v)
        pads = ((0, 0), (0, 0), (0, max_seq - s), (0, 0))
        cache.update(
            k=jnp.pad(kq, padw), v=jnp.pad(vq, padw),
            k_scale=jnp.pad(ksc, pads), v_scale=jnp.pad(vsc, pads),
        )
    else:
        if max_seq > s:
            k, v = jnp.pad(k, padw), jnp.pad(v, padw)
        cache.update(k=k, v=v)
    return logits[:, -1], cache


def decode_step(params, cfg, cache, token, pos):
    """One decode step. token: (B,) int32; pos: scalar int32 (abs position).

    With ``cfg.sliding_window > 0`` the cache is a ring buffer of
    ``window`` slots (cache seq dim == window) — O(window) per token.
    """
    emb = params["embed"]["tok"]
    x = emb[token][:, None, :].astype(cfg.activation_dtype)  # (B,1,d)
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window
    s_cache = cache["k"].shape[2]
    slot = jnp.where(window > 0, pos % jnp.maximum(s_cache, 1), pos).astype(jnp.int32)

    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    if cfg.mrope_sections:
        p3 = jnp.broadcast_to(posb[None], (3, b, 1))
        cos, sin = L.rope_cos_sin(p3, hd, cfg.rope_theta, cfg.mrope_sections)
    else:
        cos, sin = L.rope_cos_sin(posb, hd, cfg.rope_theta)

    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1)), (0, slot)
    )

    quant = cfg.kv_cache_dtype == "int8"
    wpos = new_pos if window > 0 else None
    length = jnp.minimum(pos + 1, s_cache)

    def body(carry, xs):
        x, aux = carry
        if quant:
            lp, kc, vc, ksc, vsc = xs
        else:
            lp, kc, vc = xs
        h = L.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h)
        q, k = L.apply_rope(q, k, cos, sin)
        if quant:
            kq, ks_ = L.quantize_kv(k)
            vq, vs_ = L.quantize_kv(v)
            kc = jax.lax.dynamic_update_slice(kc, kq, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, vq, (0, slot, 0, 0))
            ksc = jax.lax.dynamic_update_slice(ksc, ks_, (0, slot, 0))
            vsc = jax.lax.dynamic_update_slice(vsc, vs_, (0, slot, 0))
            attn = L.decode_attention_q(
                q[:, 0], kc, vc, ksc, vsc, length, window_pos=wpos
            )
        else:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))
            attn = L.decode_attention(q[:, 0], kc, vc, length, window_pos=wpos)
        x = x + L.attn_out(lp["attn"], attn[:, None], x.dtype)
        h2 = L.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        if cfg.is_moe:
            y, a = MOE.moe_apply(lp["moe"], cfg, h2)
        else:
            y, a = L.mlp_apply(lp["mlp"], h2), jnp.zeros((), jnp.float32)
        ys = (kc, vc, ksc, vsc) if quant else (kc, vc)
        return (x + y, aux + a), ys

    if quant:
        xs_in = (params["layers"], cache["k"], cache["v"],
                 cache["k_scale"], cache["v_scale"])
    else:
        xs_in = (params["layers"], cache["k"], cache["v"])
    (x, _), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs_in)
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params, cfg, x)[:, 0]
    new_cache = {"pos": new_pos, "length": length}
    if quant:
        new_cache.update(k=ys[0], v=ys[1], k_scale=ys[2], v_scale=ys[3])
    else:
        new_cache.update(k=ys[0], v=ys[1])
    return logits, new_cache
