"""Model registry: one uniform interface over all architecture families.

``build_model(cfg)`` returns a ``Model`` whose functions close over nothing —
params/caches are explicit pytrees — so they can be jitted, pjit-sharded, or
vmapped over federated clients by the AFL core.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.sharding.rules import ParamSpec, axes_tree, init_params


@dataclasses.dataclass(eq=False)  # identity hash: usable as a jit static arg
class Model:
    cfg: ModelConfig
    specs: dict
    loss_fn: Callable  # (params, cfg, batch) -> scalar loss
    forward: Callable
    decode_step: Optional[Callable] = None  # (params, cfg, cache, token, pos)
    prefill: Optional[Callable] = None
    init_cache: Optional[Callable] = None  # (cfg, batch, max_seq) -> cache
    cache_axes: Optional[Callable] = None  # (cfg) -> logical dims tree
    encode: Optional[Callable] = None  # enc-dec only

    def init(self, rng, dtype=None):
        return init_params(self.specs, rng, jnp.dtype(self.cfg.param_dtype))

    def param_axes(self):
        return axes_tree(self.specs)

    def num_params(self) -> int:
        leaves = jax.tree.leaves(
            self.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
        )
        return sum(int(np.prod(s.shape)) for s in leaves)


def _transformer_cache_axes(cfg):
    ax = {
        "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "seq", "kv_heads", "head_dim"),
        "pos": ("batch", "seq"),
        "length": (),
    }
    if cfg.kv_cache_dtype == "int8":
        ax["k_scale"] = ("layers", "batch", "seq", "kv_heads")
        ax["v_scale"] = ("layers", "batch", "seq", "kv_heads")
    return ax


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe"):
        from repro.models import transformer as T

        return Model(
            cfg, T.param_specs(cfg), T.loss_fn, T.forward,
            decode_step=T.decode_step, prefill=T.prefill,
            init_cache=T.init_cache,
            cache_axes=_transformer_cache_axes,
        )
    if fam == "vlm":
        from repro.models import transformer as T
        from repro.models import vlm as V

        return Model(
            cfg, V.param_specs(cfg), V.loss_fn, V.forward,
            decode_step=V.decode_step, prefill=V.prefill,
            init_cache=V.init_cache,
            cache_axes=_transformer_cache_axes,
        )
    if fam == "ssm":
        from repro.models import mamba2 as M

        return Model(
            cfg, M.param_specs(cfg), M.loss_fn, M.forward,
            decode_step=M.decode_step, prefill=M.prefill,
            init_cache=M.init_cache, cache_axes=M.cache_axes,
        )
    if fam == "hybrid":
        from repro.models import hybrid as H

        return Model(
            cfg, H.param_specs(cfg), H.loss_fn, H.forward,
            decode_step=H.decode_step, prefill=H.prefill,
            init_cache=H.init_cache, cache_axes=H.cache_axes,
        )
    if fam == "audio":
        from repro.models import encdec as E

        return Model(
            cfg, E.param_specs(cfg), E.loss_fn, E.forward,
            decode_step=E.decode_step, prefill=E.prefill,
            init_cache=E.init_cache, cache_axes=E.cache_axes, encode=E.encode,
        )
    if fam == "vision":
        from repro.models import resnet as R

        return Model(cfg, R.param_specs(cfg), R.loss_fn, R.forward)
    if fam == "trajectory":
        from repro.models import lanegcn as G

        return Model(cfg, G.param_specs(cfg), G.loss_fn, G.forward)
    raise ValueError(f"unknown family {fam!r}")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs + logical dims) per (arch, input shape)
# ---------------------------------------------------------------------------

N_IMG_PATCHES = 256  # stub vision patches for VLM train/prefill


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Returns (tree of ShapeDtypeStruct, tree of logical dims) for the step
    inputs (excluding params and caches)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt=i32):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            n_img = min(N_IMG_PATCHES, s // 2)
            n_txt = s - n_img
            tree = {
                "tokens": sds((b, n_txt)),
                "labels": sds((b, n_txt)),
                "vision_embeds": sds((b, n_img, cfg.d_model), jnp.bfloat16),
            }
            dims = {
                "tokens": ("batch", "seq"),
                "labels": ("batch", "seq"),
                "vision_embeds": ("batch", "seq", "embed"),
            }
        elif cfg.family == "audio":
            tree = {
                "tokens": sds((b, s)),
                "labels": sds((b, s)),
                "frames": sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
            }
            dims = {
                "tokens": ("batch", "seq"),
                "labels": ("batch", "seq"),
                "frames": ("batch", "pos", "embed"),
            }
        else:
            tree = {"tokens": sds((b, s)), "labels": sds((b, s))}
            dims = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        if shape.kind == "prefill":
            tree.pop("labels")
            dims.pop("labels")
        return tree, dims

    # decode: one new token against a seq_len-deep cache
    tree = {"token": sds((b,)), "pos": sds(())}
    dims = {"token": ("batch",), "pos": ()}
    return tree, dims


def demo_batch(cfg: ModelConfig, batch: int, seq: int, rng: np.random.Generator):
    """Concrete small arrays for smoke tests (reduced configs)."""
    if cfg.family == "vision":
        return {
            "images": rng.normal(0, 1, (batch, 32, 32, 3)).astype(np.float32),
            "labels": rng.integers(0, cfg.vocab_size, batch).astype(np.int32),
        }
    if cfg.family == "trajectory":
        return {
            "past": rng.normal(0, 1, (batch, 20, 2)).astype(np.float32),
            "lanes": rng.normal(0, 1, (batch, 32, 2)).astype(np.float32),
            "future": rng.normal(0, 1, (batch, 30, 2)).astype(np.float32),
        }
    out = {
        "tokens": rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32),
    }
    if cfg.family == "vlm":
        n_img = 16
        out["vision_embeds"] = rng.normal(0, 0.02, (batch, n_img, cfg.d_model)).astype(
            np.float32
        )
    if cfg.family == "audio":
        out["frames"] = rng.normal(0, 0.02, (batch, cfg.encoder_seq, cfg.d_model)).astype(
            np.float32
        )
    return out
