"""Mamba2 (SSD — state-space duality) blocks [arXiv:2405.21060].

Chunked SSD: the sequence is split into chunks of ``cfg.ssm_chunk``;
intra-chunk terms use the quadratic (attention-like) form, inter-chunk
terms propagate the (H, P, N) state with a linear scan over chunks.
Decode is the O(1)-per-token recurrent update — this is why the SSM archs
run ``long_500k`` natively.

Projections are kept as separate tensors (wz/wx/wB/wC/wdt) so the logical
sharding rules can put ``d_inner`` (and thus SSD heads) on the ``model``
axis without splitting a fused in_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding.rules import ParamSpec

HEAD_P = 64  # SSD value-head dim


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = cfg.ssm_heads or d_inner // HEAD_P
    return d_inner, heads, d_inner // heads, cfg.ssm_state


def mamba_specs(cfg) -> dict:
    d_inner, h, p, n = dims(cfg)
    d = cfg.d_model
    k = cfg.conv_kernel
    return {
        "wz": ParamSpec((d, d_inner), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, d_inner), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, n), ("embed", "ssm_state")),
        "wC": ParamSpec((d, n), ("embed", "ssm_state")),
        "wdt": ParamSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": ParamSpec((k, d_inner), ("conv", "ssm_inner"), init="small"),
        "conv_B": ParamSpec((k, n), ("conv", "ssm_state"), init="small"),
        "conv_C": ParamSpec((k, n), ("conv", "ssm_state"), init="small"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "a_log": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "wo": ParamSpec((d_inner, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C).

    If ``state`` (B,K-1,C) is given (decode), returns (y, new_state)."""
    k = w.shape[0]
    if state is not None:
        xs = jnp.concatenate([state, x], axis=1)  # (B, K-1+S, C)
        new_state = xs[:, -(k - 1) :, :] if k > 1 else jnp.zeros_like(state)
    else:
        xs = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = None
    y = sum(xs[:, i : i + x.shape[1], :] * w[i][None, None] for i in range(k))
    y = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    return (y, new_state) if state is not None else y


def _segsum(a):
    """a: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<k<=i} a_k (i>=j)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, b, c, chunk: int, initial_state=None):
    """Chunked SSD scan (pure-jnp reference; the Pallas kernel mirrors this).

    x: (B,S,H,P) discrete inputs (already dt-scaled); a: (B,S,H) log-decays
    (dt * A, negative); b, c: (B,S,N).  Returns y: (B,S,H,P), final state
    (B,H,P,N).  All internals f32.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xf = x.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    af = a.astype(jnp.float32).reshape(bsz, nc, q, h).transpose(0, 3, 1, 2)  # (B,H,nc,Q)
    bf = b.astype(jnp.float32).reshape(bsz, nc, q, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, q, n)

    a_cum = jnp.cumsum(af, axis=-1)  # (B,H,nc,Q)
    lmat = jnp.exp(_segsum(af))  # (B,H,nc,Q,Q)
    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", cf, bf, lmat, xf)
    # states emitted by each chunk
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", bf, decay_states, xf)
    # inter-chunk linear scan
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,H,nc)
    init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def scan_body(carry, xs):
        st_c, dec_c = xs  # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev

    st_seq = states.transpose(1, 0, 2, 3, 4)  # (nc,B,H,P,N)
    dec_seq = chunk_decay.transpose(2, 0, 1)  # (nc,B,H)
    final, prevs = jax.lax.scan(scan_body, init, (st_seq, dec_seq))
    prevs = prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)
    # inter-chunk contribution
    state_decay_out = jnp.exp(a_cum)  # (B,H,nc,Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", cf, prevs, state_decay_out)
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final


def ssd_decode_step(state, x, a, b, c):
    """O(1) recurrent update. state: (B,H,P,N); x: (B,H,P); a: (B,H); b,c: (B,N)."""
    dec = jnp.exp(a.astype(jnp.float32))[..., None, None]
    upd = x.astype(jnp.float32)[..., None] * b.astype(jnp.float32)[:, None, None, :]
    new = state * dec + upd
    y = jnp.einsum("bhpn,bn->bhp", new, c.astype(jnp.float32))
    return y.astype(x.dtype), new


def mamba_block(p, cfg, x, conv_state=None, ssm_state=None, collect_cache=False):
    """Full Mamba2 block. x: (B,S,d).

    Training: states None -> returns (y, final_ssm_state).
    Prefill (collect_cache): returns (y, conv_tails, final_ssm_state).
    Decode (S==1): pass states -> returns (y, new_conv, new_ssm).
    """
    d_inner, h, pdim, n = dims(cfg)
    dt_ = x.dtype
    z = jnp.einsum("bsd,di->bsi", x, p["wz"].astype(dt_))
    xin = jnp.einsum("bsd,di->bsi", x, p["wx"].astype(dt_))
    bin_ = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(dt_))
    cin = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))

    decode = conv_state is not None
    if decode:
        xin, cx = _causal_conv(xin, p["conv_x"].astype(dt_), conv_state["x"])
        bin_, cb = _causal_conv(bin_, p["conv_B"].astype(dt_), conv_state["B"])
        cin, cc = _causal_conv(cin, p["conv_C"].astype(dt_), conv_state["C"])
        new_conv = {"x": cx, "B": cb, "C": cc}
    else:
        kk = p["conv_x"].shape[0]
        if collect_cache:  # pre-conv tails become the decode conv state
            new_conv = {
                "x": xin[:, -(kk - 1) :, :],
                "B": bin_[:, -(kk - 1) :, :],
                "C": cin[:, -(kk - 1) :, :],
            }
        xin = _causal_conv(xin, p["conv_x"].astype(dt_))
        bin_ = _causal_conv(bin_, p["conv_B"].astype(dt_))
        cin = _causal_conv(cin, p["conv_C"].astype(dt_))

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative decay rates
    xh = xin.reshape(*xin.shape[:2], h, pdim)
    x_disc = xh.astype(jnp.float32) * dt[..., None]
    log_decay = dt * a  # (B,S,H)

    if decode:
        y1, new_ssm = ssd_decode_step(
            ssm_state, x_disc[:, 0], log_decay[:, 0], bin_[:, 0], cin[:, 0]
        )
        y = y1[:, None]
    elif jax.default_backend() == "tpu" and x_disc.shape[1] % cfg.ssm_chunk == 0:
        # chunked SSD Pallas kernel (repro/kernels/ssd_scan.py)
        from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel

        y, new_ssm = _ssd_kernel(
            x_disc, log_decay, bin_, cin, chunk=cfg.ssm_chunk, interpret=False
        )
    else:
        y, new_ssm = ssd_chunked(x_disc, log_decay, bin_, cin, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xin.shape[:2], d_inner).astype(dt_)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"].astype(dt_))
    if decode:
        return out, new_conv, new_ssm
    if collect_cache:
        return out, new_conv, new_ssm
    return out, new_ssm


# ---------------------------------------------------------------------------
# Full model (attention-free LM)
# ---------------------------------------------------------------------------


def block_specs(cfg) -> dict:
    return {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "mamba": mamba_specs(cfg),
    }


def param_specs(cfg) -> dict:
    from repro.models.transformer import stack_specs

    return {
        "embed": L.embed_specs(cfg),
        "layers": stack_specs(block_specs(cfg), cfg.num_layers),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), init="ones"),
        "unembed": {
            "w": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="small")
        },
    }


def forward(params, cfg, tokens, **_):
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)

    def body(carry, lp):
        x = carry
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, _ = mamba_block(lp["mamba"], cfg, h)
        return x + y, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"])


def init_cache(cfg, batch: int, max_seq: int = 0):
    """Recurrent cache: conv tails + SSD state per layer. O(1) in seq length."""
    d_inner, h, p, n = dims(cfg)
    k = cfg.conv_kernel
    lcount = cfg.num_layers
    dt = cfg.activation_dtype
    return {
        "conv_x": jnp.zeros((lcount, batch, k - 1, d_inner), dt),
        "conv_B": jnp.zeros((lcount, batch, k - 1, n), dt),
        "conv_C": jnp.zeros((lcount, batch, k - 1, n), dt),
        "ssm": jnp.zeros((lcount, batch, h, p, n), jnp.float32),
    }


def cache_axes(cfg):
    return {
        "conv_x": ("layers", "batch", "conv", "ssm_inner"),
        "conv_B": ("layers", "batch", "conv", "ssm_state"),
        "conv_C": ("layers", "batch", "conv", "ssm_state"),
        "ssm": ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
    }


def prefill(params, cfg, tokens, **_):
    """Run the prompt, return (last-token logits, recurrent cache)."""
    x = params["embed"]["tok"][tokens].astype(cfg.activation_dtype)

    def body(carry, lp):
        x = carry
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, conv, ssm = mamba_block(lp["mamba"], cfg, h, collect_cache=True)
        return x + y, (conv["x"], conv["B"], conv["C"], ssm)

    x, (cx, cb, cc, ssm) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"]["w"].astype(x.dtype))
    return logits, {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": ssm}


def decode_step(params, cfg, cache, token, pos):
    x = params["embed"]["tok"][token][:, None, :].astype(cfg.activation_dtype)

    def body(carry, xs):
        x = carry
        lp, cx, cb, cc, ssm = xs
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, new_conv, new_ssm = mamba_block(
            lp["mamba"], cfg, h, conv_state={"x": cx, "B": cb, "C": cc}, ssm_state=ssm
        )
        return x + y, (new_conv["x"], new_conv["B"], new_conv["C"], new_ssm)

    x, (cx, cb, cc, ssm) = jax.lax.scan(
        body,
        x,
        (params["layers"], cache["conv_x"], cache["conv_B"], cache["conv_C"], cache["ssm"]),
    )
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"]["w"].astype(x.dtype))[:, 0]
    return logits, {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": ssm}
