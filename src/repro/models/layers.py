"""Shared neural building blocks (pure functions over param dicts).

Conventions:
* activations default to ``cfg.dtype`` (bf16), reductions in f32;
* attention is memory-efficient (online-softmax over KV chunks) so that
  32k-token prefill lowers without materialising S x S score matrices;
* GQA is implemented by repeating KV heads at compute time;
* RoPE supports plain rotary and Qwen2-VL M-RoPE (t/h/w sections);
* decode uses a KV cache, optionally a ring buffer (sliding window) which is
  what makes 500k-context decode sub-quadratic for full-attention archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope_cos_sin(positions, head_dim: int, theta: float, sections: Tuple[int, ...] = ()):
    """cos/sin tables.

    positions: (B, S) for plain RoPE or (3, B, S) for M-RoPE.
    Returns (cos, sin) of shape (B, S, head_dim/2) in f32.
    """
    inv = rope_freqs(head_dim, theta)  # (hd/2,)
    if not sections:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B,S,hd/2)
        return jnp.cos(ang), jnp.sin(ang)
    # M-RoPE: frequency slots are split into contiguous (t, h, w) sections and
    # each section consumes the matching positional stream (Qwen2-VL §2.1).
    assert positions.ndim == 3 and positions.shape[0] == len(sections)
    ang_all = positions.astype(jnp.float32)[..., None] * inv  # (3,B,S,hd/2)
    pieces, off = [], 0
    for i, sec in enumerate(sections):
        pieces.append(ang_all[i, ..., off : off + sec])
        off += sec
    ang = jnp.concatenate(pieces, axis=-1)  # (B,S,hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(q, k, cos, sin):
    """q: (B,S,H,D), k: (B,S,KV,D); cos/sin: (B,S,D/2)."""
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    qf = _rotate(q.astype(jnp.float32), c, s).astype(q.dtype)
    kf = _rotate(k.astype(jnp.float32), c, s).astype(k.dtype)
    return qf, kf


def text_mrope_positions(batch: int, seq: int) -> jnp.ndarray:
    """For pure-text streams all three M-RoPE position channels coincide."""
    pos = jnp.broadcast_to(jnp.arange(seq)[None, :], (batch, seq))
    return jnp.broadcast_to(pos[None], (3, batch, seq))


# ---------------------------------------------------------------------------
# Attention (online-softmax over KV chunks)
# ---------------------------------------------------------------------------


def _repeat_kv(k, groups: int):
    if groups == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, d)).reshape(b, s, kv * groups, d)


def causal_attention(
    q,
    k,
    v,
    *,
    chunk: int = 1024,
    sliding_window: int = 0,
    causal: bool = True,
    q_offset: int = 0,
):
    """Memory-efficient attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D).  Online softmax accumulates over
    KV chunks so peak memory is O(Sq * chunk) per head rather than O(Sq*Sk).
    ``q_offset`` is the absolute position of q[0] (for prefill continuation).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    groups = h // max(kv, 1)
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)

    qf = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, xs):
        m, l, acc, idx = carry
        kb, vb = xs
        k_pos = idx * chunk + jnp.arange(chunk)
        s_ = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32)) * scale
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < sliding_window
        mask &= (k_pos < sk)[None, :]
        s_ = jnp.where(mask[None, None], s_, -jnp.inf)
        m_new = jnp.maximum(m, s_.max(-1))
        p = jnp.exp(s_ - m_new[..., None])
        p = jnp.where(jnp.isfinite(m_new)[..., None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.asarray(0)), (kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,D)


def decode_attention(q, k_cache, v_cache, length, *, window_pos=None):
    """Single-token attention against a KV cache.

    q: (B, H, D); caches: (B, S, KV, D); ``length``: number of valid cache
    entries (scalar or (B,)).  ``window_pos`` (ring-buffer mode): absolute
    positions per cache slot (B, S) used for masking instead of slot index.
    """
    b, s, kv, d = k_cache.shape
    h = q.shape[1]
    if window_pos is None and jax.default_backend() == "tpu":
        # flash-decode Pallas kernel (repro/kernels/decode_attn.py)
        from repro.kernels import ops as KOPS

        return KOPS.decode_attn(q, k_cache, v_cache, length, impl="pallas")
    groups = h // max(kv, 1)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = _replicate(q.astype(jnp.float32).reshape(b, kv, groups, d))
    kf = k_cache.astype(jnp.float32)
    s_ = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * scale
    if window_pos is None:
        valid = jnp.arange(s)[None, :] < jnp.reshape(length, (-1, 1))
    else:
        valid = window_pos >= 0
    s_ = jnp.where(valid[:, None, None, :], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def quantize_kv(x):
    """Symmetric per-(token, head) int8 quantisation. x: (B,S,KV,D).

    Returns (int8 values, f32 scales (B,S,KV)). Beyond-paper serving
    optimisation: halves decode KV-cache HBM traffic (§Perf)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)  # (B,S,KV)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _replicate(x):
    """Force a (tiny) operand fully replicated before a decode-attention
    einsum.  When GQA head counts don't align with the model axis, GSPMD
    resolves the q-vs-cache sharding mismatch by ALL-GATHERING the CACHE
    (measured: 537 MB f32/step on qwen3-moe decode_32k; pinning the cache's
    own sharding instead made GSPMD permute it — both refuted, §Perf
    C-series).  Replicating q (B*H*D ~ 100 KB) makes the partial-score +
    all-reduce strategy the natural choice.  No-op outside a mesh context."""
    try:
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P())
    except Exception:  # no mesh: leave to propagation
        return x


def decode_attention_q(q, kq, vq, k_scale, v_scale, length, *, window_pos=None):
    """decode_attention over an int8 cache; scales applied to score/prob
    rows so the dequantised cache never materialises.

    q: (B,H,D); kq, vq: (B,S,KV,D) int8; scales: (B,S,KV) f32."""
    b, s, kv, d = kq.shape
    h = q.shape[1]
    groups = h // max(kv, 1)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qf = _replicate(q.astype(jnp.float32).reshape(b, kv, groups, d))
    s_ = jnp.einsum("bkgd,bskd->bkgs", qf, kq.astype(jnp.float32)) * scale
    s_ = s_ * k_scale.transpose(0, 2, 1)[:, :, None, :]  # (B,KV,1,S)
    if window_pos is None:
        valid = jnp.arange(s)[None, :] < jnp.reshape(length, (-1, 1))
    else:
        valid = window_pos >= 0
    s_ = jnp.where(valid[:, None, None, :], s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    p = p * v_scale.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgs,bskd->bkgd", p, vq.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block parameter specs + apply
# ---------------------------------------------------------------------------


def attn_specs(cfg) -> dict:
    hd = cfg.resolved_head_dim
    sp = {
        "wq": ParamSpec((cfg.d_model, cfg.num_heads, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((cfg.d_model, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((cfg.d_model, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((cfg.num_heads, hd, cfg.d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = ParamSpec((cfg.num_heads, hd), ("heads", "head_dim"), init="zeros")
        sp["bk"] = ParamSpec((cfg.num_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
        sp["bv"] = ParamSpec((cfg.num_kv_heads, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sp["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        sp["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return sp


def attn_qkv(p, cfg, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_out(p, x_attn, dtype):
    return jnp.einsum("bshk,hkd->bsd", x_attn, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# MLP (SwiGLU) + embeddings
# ---------------------------------------------------------------------------


def mlp_specs(cfg, d_ff: Optional[int] = None) -> dict:
    ff = d_ff or cfg.d_ff
    return {
        "wi_gate": ParamSpec((cfg.d_model, ff), ("embed", "mlp")),
        "wi_up": ParamSpec((cfg.d_model, ff), ("embed", "mlp")),
        "wo": ParamSpec((ff, cfg.d_model), ("mlp", "embed")),
    }


def mlp_apply(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


def cross_entropy(logits, labels):
    """Sharding-friendly next-token CE (mean over all positions).

    Uses logsumexp + a one-hot contraction instead of ``take_along_axis`` —
    a vocab-sharded logits tensor then needs only small all-reduces over the
    vocab shards, not an all-gather of the full (T, V) logits (§Perf B1).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)  # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    label_logit = jnp.einsum("...v,...v->...", lf, onehot)
    return jnp.mean(lse - label_logit)


def embed_specs(cfg) -> dict:
    return {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="small")}


def unembed(params, cfg, x):
    """Project to vocab logits (tied or untied)."""
    w = params["embed"]["tok"] if cfg.tie_embeddings else params["unembed"]["w"]
    return jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype)) if cfg.tie_embeddings else jnp.einsum(
        "bsd,dv->bsv", x, w.astype(x.dtype)
    )
