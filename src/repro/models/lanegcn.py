"""LaneGCN-lite for Argoverse-style motion forecasting (paper §VI-C).

ActorNet: 1D conv stack over the past trajectory; MapNet: graph convolutions
over lane-centreline nodes (chain adjacency); FusionNet: actor->map and
map->actor attention; regression head predicts 30 future (x, y) offsets.
Metric/loss: ADE (mean Euclidean displacement), as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec


def param_specs(cfg) -> dict:
    d = cfg.d_model
    future = 30

    def lin(i, o, name_in="mlp"):
        return {
            "w": ParamSpec((i, o), (None, "mlp")),
            "b": ParamSpec((o,), ("mlp",), init="zeros"),
        }

    return {
        "actor_conv1": {"w": ParamSpec((3, 2, d), (None, None, "mlp")),
                        "b": ParamSpec((d,), ("mlp",), init="zeros")},
        "actor_conv2": {"w": ParamSpec((3, d, d), (None, None, "mlp")),
                        "b": ParamSpec((d,), ("mlp",), init="zeros")},
        "map_in": lin(2, d),
        "gcn1": lin(2 * d, d),
        "gcn2": lin(2 * d, d),
        "fuse_q": lin(d, d),
        "fuse_k": lin(d, d),
        "fuse_v": lin(d, d),
        "head1": lin(2 * d, cfg.d_ff),
        "head2": lin(cfg.d_ff, future * 2),
    }


def _lin(p, x):
    return x @ p["w"] + p["b"]


def _conv1d(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )
    return jax.nn.relu(y + p["b"])


def forward(params, cfg, batch_past, batch_lanes, **_):
    """past: (B, 20, 2); lanes: (B, M, 2) -> predicted future (B, 30, 2)."""
    x = batch_past.astype(jnp.float32)
    a = _conv1d(params["actor_conv1"], x)
    a = _conv1d(params["actor_conv2"], a, stride=2)
    actor = jnp.max(a, axis=1)  # (B, d)

    m = jax.nn.relu(_lin(params["map_in"], batch_lanes.astype(jnp.float32)))  # (B,M,d)
    # chain-adjacency graph conv: neighbour mean = (prev + next)/2
    for key in ("gcn1", "gcn2"):
        prev = jnp.roll(m, 1, axis=1)
        nxt = jnp.roll(m, -1, axis=1)
        neigh = 0.5 * (prev + nxt)
        m = jax.nn.relu(_lin(params[key], jnp.concatenate([m, neigh], -1)))

    q = _lin(params["fuse_q"], actor)[:, None, :]  # (B,1,d)
    k = _lin(params["fuse_k"], m)
    v = _lin(params["fuse_v"], m)
    att = jax.nn.softmax(
        jnp.einsum("bqd,bmd->bqm", q, k) / jnp.sqrt(cfg.d_model).astype(jnp.float32), -1
    )
    ctx = jnp.einsum("bqm,bmd->bqd", att, v)[:, 0]  # (B,d)

    h = jax.nn.relu(_lin(params["head1"], jnp.concatenate([actor, ctx], -1)))
    out = _lin(params["head2"], h).reshape(-1, 30, 2)
    return out, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg, batch):
    pred, _ = forward(params, cfg, batch["past"], batch["lanes"])
    return ade(pred, batch["future"])


def ade(pred, target):
    """Average displacement error (paper's Argoverse metric)."""
    return jnp.mean(jnp.linalg.norm(pred - target.astype(jnp.float32), axis=-1))
