"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparsify_ef_ref(x: jax.Array, threshold: jax.Array):
    """Fused sparsify + error-feedback reference.

    x: (n,) any float dtype; threshold: scalar f32.
    Returns (upload, error, count): upload = x where |x|>=t else 0,
    error = x - upload, count = #selected (f32).
    """
    mask = jnp.abs(x.astype(jnp.float32)) >= threshold
    upload = jnp.where(mask, x, jnp.zeros_like(x))
    error = jnp.where(mask, jnp.zeros_like(x), x)
    return upload, error, jnp.sum(mask).astype(jnp.float32)


def sparsify_quantize_ef_ref(x: jax.Array, threshold, step, levels, seed,
                             base: int = 0):
    """Fused sparsify + stochastic quantize + error-feedback reference.

    x: any shape/float dtype; threshold/step/levels: scalar f32; seed:
    scalar int32; base: static global element offset (multi-leaf messages).
    Returns (upload, error, count): upload = dequantised b-bit value where
    |x| >= t else 0, error = x - upload (so the EF memory absorbs BOTH the
    dropped coordinates and the quantisation residual of kept ones),
    count = #selected (f32).  Dither is the counter-based hash of
    ``compression.quant``, so the upload and count are bit-identical to the
    Pallas kernel; the error may differ by one rounding where XLA fuses
    ``x - q*step`` into an FMA (allclose in tests).
    """
    from repro.compression.quant import dither_u01

    xf = x.astype(jnp.float32)
    mask = jnp.abs(xf) >= threshold
    idx = base + jnp.arange(x.size).reshape(x.shape)
    u = dither_u01(jnp.asarray(seed), idx)
    q = jnp.clip(jnp.floor(xf / step + u), -levels, levels) * step
    upload = jnp.where(mask, q, 0.0).astype(x.dtype)
    error = (xf - upload.astype(jnp.float32)).astype(x.dtype)
    return upload, error, jnp.sum(mask).astype(jnp.float32)


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array, length):
    """Single-token GQA decode attention reference.

    q: (B, H, D); k, v: (B, S, KV, D); length: scalar or (B,) valid entries.
    Returns (B, H, D).
    """
    b, s, kv, d = k.shape
    h = q.shape[1]
    groups = h // kv
    qf = q.astype(jnp.float32).reshape(b, kv, groups, d)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.asarray(d, jnp.float32))
    valid = jnp.arange(s)[None, :] < jnp.reshape(jnp.asarray(length), (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def ssd_scan_ref(x, a, b, c, initial_state=None):
    """Sequential SSD recurrence reference (exact, O(S) scan).

    x: (B,S,H,P) dt-scaled inputs; a: (B,S,H) log decays; b,c: (B,S,N).
    Returns y: (B,S,H,P), final_state: (B,H,P,N). All f32 internally.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    st0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, t):
        st = carry
        dec = jnp.exp(af[:, t])[..., None, None]  # (B,H,1,1)
        upd = xf[:, t][..., None] * bf[:, t][:, None, None, :]  # (B,H,P,N)
        st = st * dec + upd
        y_t = jnp.einsum("bhpn,bn->bhp", st, cf[:, t])
        return st, y_t

    st, ys = jax.lax.scan(step, st0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3), st
