"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; production dispatch in ops.py falls back to the jnp oracle on
non-TPU backends).

  sparsify_ef  fused threshold-mask + error-feedback update (the paper's
               per-round sparsification pass)
  decode_attn  flash-decode attention for 32k-500k KV caches
  ssd_scan     chunked Mamba2/SSD scan with VMEM-resident chunk state
"""
