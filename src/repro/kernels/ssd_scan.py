"""Pallas TPU kernel: chunked SSD (Mamba2) scan.

Computes, per (batch, head), the state-space-duality recurrence in chunks:
intra-chunk quadratic term ((C B^T) ⊙ L) X with L = exp(segsum(a)), plus the
inter-chunk state recurrence carried in a revisited (P, N) output block —
the chunk axis is the sequential (last) grid dimension, so the state flows
chunk-to-chunk entirely inside VMEM instead of bouncing (B,H,P,N) states
through HBM between chunks as the pure-jnp scan does.

Grid: (B, H, S/Q).  Blocks: x (Q, P), a (Q,), b/c (Q, N), y (Q, P),
state (P, N) — Q and P MXU-aligned (Q=128-256, P=64, N=64-128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    ci = pl.program_id(2)
    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    a = a_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    b = b_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)  # (Q, N)
    q = x.shape[0]

    @pl.when(ci == 0)
    def _init():
        st_ref[0, 0] = jnp.zeros_like(st_ref[0, 0])

    st = st_ref[0, 0]  # (P, N)

    a_cum = jnp.cumsum(a)  # (Q,)
    diff = a_cum[:, None] - a_cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (q, q), 1
    )
    lmat = jnp.where(tri, jnp.exp(diff), 0.0)  # (Q, Q)

    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y_diag = jnp.dot(cb * lmat, x, preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk contribution from the carried state
    y_off = jnp.dot(c, st.T, preferred_element_type=jnp.float32) * jnp.exp(a_cum)[:, None]

    # new chunk state: sum_s exp(a_total - a_cum[s]) * x[s] b[s]^T
    decay = jnp.exp(a_cum[-1] - a_cum)  # (Q,)
    st_new = st * jnp.exp(a_cum[-1]) + jnp.dot(
        (x * decay[:, None]).T, b, preferred_element_type=jnp.float32
    )

    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)
    st_ref[0, 0] = st_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
             chunk: int = 128, interpret: bool = True):
    """x: (B,S,H,P) dt-scaled input; a: (B,S,H) log decay; b,c: (B,S,N).

    Returns (y (B,S,H,P) f32, final_state (B,H,P,N) f32). S % chunk == 0.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    xr = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, q, p)
    ar = a.transpose(0, 2, 1).reshape(bsz, h, nc, q)
    br = jnp.broadcast_to(b.reshape(bsz, 1, nc, q, n), (bsz, h, nc, q, n))
    cr = jnp.broadcast_to(c.reshape(bsz, 1, nc, q, n), (bsz, h, nc, q, n))

    y, st = pl.pallas_call(
        _kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, n), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, ar, br, cr)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    return y, st
