"""Pallas TPU kernel: flash-decode attention (one query token vs a deep KV
cache).

decode_32k / long_500k are memory-bound: the step reads the whole cache
(B x S x KV x D x 2) once.  The kernel streams KV blocks through VMEM with
an online-softmax accumulator so no (B, H, S) score tensor ever reaches HBM
— unlike the naive jnp path which materialises scores + probabilities
(~2x B*H*S*4 bytes of extra HBM traffic at S = 32k-500k).

Grid: (B, KV_heads, S/BLOCK_S); the last axis iterates sequentially on TPU,
so the running (m, l, acc) state lives in revisited output blocks
(accumulator pattern), finalised as acc / l in the jit wrapper.
GQA: each KV head serves G = H/KV query rows; blocks are (G, D) x (BS, D)
MXU matmuls with D = 128-aligned head dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 512


def _kernel(q_ref, k_ref, v_ref, len_ref, m_ref, l_ref, acc_ref):
    sblk = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (BS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (BS, D)
    length = len_ref[0]
    d = q.shape[-1]
    scale = jax.lax.rsqrt(jnp.asarray(d, jnp.float32))

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, BS)
    pos = sblk * BLOCK_S + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, -jnp.inf)

    @pl.when(sblk == 0)
    def _init():
        m_ref[0, 0] = jnp.full_like(m_ref[0, 0], -jnp.inf)
        l_ref[0, 0] = jnp.zeros_like(l_ref[0, 0])
        acc_ref[0, 0] = jnp.zeros_like(acc_ref[0, 0])

    m_prev = m_ref[0, 0]  # (G,)
    l_prev = l_ref[0, 0]
    acc_prev = acc_ref[0, 0]  # (G, D)

    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(jnp.isfinite(m_new)[:, None], p, 0.0)
    corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_new = acc_prev * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_new
    acc_ref[0, 0] = acc_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attn(q: jax.Array, k: jax.Array, v: jax.Array, length, *,
                interpret: bool = True):
    """q: (B, H, D); k, v: (B, S, KV, D); length: scalar valid entries.

    Returns (B, H, D) in q.dtype.
    """
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qr = q.reshape(b, kv, g, d)
    blocks = (s + BLOCK_S - 1) // BLOCK_S
    pad = blocks * BLOCK_S - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lv = jnp.minimum(jnp.asarray(length, jnp.int32), s).reshape(1)

    m, l, acc = pl.pallas_call(
        _kernel,
        grid=(b, kv, blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, BLOCK_S, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, BLOCK_S, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1,), lambda bi, ki, si: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
            pl.BlockSpec((1, 1, g), lambda bi, ki, si: (bi, ki, 0)),
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, kv, g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v, lv)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, h, d).astype(q.dtype)
