"""Pallas TPU kernel: fused sparsify + error-feedback update.

The paper's per-round hot spot: every contacted device transforms its
upload vector x (model-sized, 6.5M-72B elements) into
    upload = x * [|x| >= t],   error = x * [|x| < t],   count = popcount
Naive jnp issues three separate elementwise passes (2 reads + 2 writes + a
reduce read).  The fused kernel streams x through VMEM once per block and
emits both outputs + a per-block partial count: 1 read + 2 writes — a 40%
HBM-traffic cut on a purely memory-bound op.

Layout: x viewed as (rows, 1024) f32/bf16, blocked (BLOCK_R, 1024) —
lane-dim 1024 = 8 x 128 keeps the VPU tiles full and 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
BLOCK_R = 256  # (256, 1024) f32 = 1 MiB per ref — comfortably inside VMEM


def _kernel(x_ref, t_ref, up_ref, err_ref, cnt_ref):
    x = x_ref[...]
    t = t_ref[0]
    mask = jnp.abs(x.astype(jnp.float32)) >= t
    zeros = jnp.zeros_like(x)
    up_ref[...] = jnp.where(mask, x, zeros)
    err_ref[...] = jnp.where(mask, zeros, x)
    cnt_ref[0] = jnp.sum(mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_ef(x: jax.Array, threshold: jax.Array, *, interpret: bool = True):
    """x: (n,) -> (upload (n,), error (n,), count scalar f32).

    Pads n up to a LANE*BLOCK_R multiple internally; padding cannot pass the
    threshold (padded with 0 and t > 0 handled via +inf sentinel for pads).
    """
    n = x.size
    t = jnp.asarray(threshold, jnp.float32).reshape(1)
    per_block = LANE * BLOCK_R
    blocks = max((n + per_block - 1) // per_block, 1)
    padded = blocks * per_block
    xp = jnp.pad(x.reshape(-1), (0, padded - n)).reshape(blocks * BLOCK_R, LANE)
    # zero padding is safe: |0| >= t only if t <= 0, and threshold_for_k
    # returns +inf for k < 1; count correction below handles t <= 0.
    up, err, cnt = pl.pallas_call(
        _kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),  # scalar threshold, broadcast
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, t)
    count = jnp.sum(cnt)
    # correct for zero padding counted when t <= 0
    pad_elems = padded - n
    count = count - jnp.where(t[0] <= 0, float(pad_elems), 0.0)
    return up.reshape(-1)[:n], err.reshape(-1)[:n], count
