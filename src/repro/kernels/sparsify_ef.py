"""Pallas TPU kernels: fused sparsify(+quantize) + error-feedback update.

The paper's per-round hot spot: every contacted device transforms its
upload vector x (model-sized, 6.5M-72B elements) into
    upload = x * [|x| >= t],   error = x * [|x| < t],   count = popcount
Naive jnp issues three separate elementwise passes (2 reads + 2 writes + a
reduce read).  The fused ``sparsify_ef`` kernel streams x through VMEM once
per block and emits both outputs + a per-block partial count: 1 read + 2
writes — a 40% HBM-traffic cut on a purely memory-bound op.

``sparsify_quantize_ef`` extends the same single pass to the compression
subsystem's quantising codecs (repro/compression): kept values are
stochastically rounded onto the ``levels``-grid with counter-based dither
(``compression.quant.dither_u01`` — pure uint32 hashing, so the upload is
bit-identical to the jnp oracle ``kernels.ref.sparsify_quantize_ef_ref``),
the quantised upload, the DEQUANTISED error memory (x - upload, absorbing
the quantisation residual), and the popcount all leave VMEM in one pass.
A separate quantise stage would re-read the masked upload from HBM;
fusing it is free — a handful of extra VPU flops on a bandwidth-bound op.

Layout: x viewed as (rows, 1024) f32/bf16, blocked (BLOCK_R, 1024) —
lane-dim 1024 = 8 x 128 keeps the VPU tiles full and 128-aligned.

``interpret=None`` (the default) auto-selects: compiled on TPU, interpret
mode elsewhere — so production entry points run the real kernel where it
matters without every call site threading backend checks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compression.quant import dither_u01

LANE = 1024
BLOCK_R = 256  # (256, 1024) f32 = 1 MiB per ref — comfortably inside VMEM


def _resolve_interpret(interpret):
    """None -> interpret only off-TPU (compiled where it matters)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _kernel(x_ref, t_ref, up_ref, err_ref, cnt_ref):
    x = x_ref[...]
    t = t_ref[0]
    mask = jnp.abs(x.astype(jnp.float32)) >= t
    zeros = jnp.zeros_like(x)
    up_ref[...] = jnp.where(mask, x, zeros)
    err_ref[...] = jnp.where(mask, zeros, x)
    cnt_ref[0] = jnp.sum(mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_ef(x: jax.Array, threshold: jax.Array, *,
                interpret: bool | None = None):
    """x: (n,) -> (upload (n,), error (n,), count scalar f32).

    Pads n up to a LANE*BLOCK_R multiple internally; padding cannot pass the
    threshold (padded with 0 and t > 0 handled via +inf sentinel for pads).
    """
    interpret = _resolve_interpret(interpret)
    n = x.size
    t = jnp.asarray(threshold, jnp.float32).reshape(1)
    per_block = LANE * BLOCK_R
    blocks = max((n + per_block - 1) // per_block, 1)
    padded = blocks * per_block
    xp = jnp.pad(x.reshape(-1), (0, padded - n)).reshape(blocks * BLOCK_R, LANE)
    # zero padding is safe: |0| >= t only if t <= 0, and threshold_for_k
    # returns +inf for k < 1; count correction below handles t <= 0.
    up, err, cnt = pl.pallas_call(
        _kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),  # scalar threshold, broadcast
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, t)
    count = jnp.sum(cnt)
    # correct for zero padding counted when t <= 0
    pad_elems = padded - n
    count = count - jnp.where(t[0] <= 0, float(pad_elems), 0.0)
    return up.reshape(-1)[:n], err.reshape(-1)[:n], count


def _kernel_q(x_ref, p_ref, seed_ref, up_ref, err_ref, cnt_ref, *, base: int):
    """params p = [threshold, step, levels]; seed: (1,) int32; base static."""
    x = x_ref[...]
    t, step, levels = p_ref[0], p_ref[1], p_ref[2]
    xf = x.astype(jnp.float32)
    mask = jnp.abs(xf) >= t
    # global flat element index of this block's elements; int32 wrap-around
    # at huge offsets is fine — the uint32 dither hash wraps identically in
    # the jnp oracle
    i = pl.program_id(0)
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    idx = base + (i * x.shape[0] + rows) * x.shape[1] + cols
    u = dither_u01(seed_ref[0], idx)
    q = jnp.clip(jnp.floor(xf / step + u), -levels, levels) * step
    upload = jnp.where(mask, q, 0.0).astype(x.dtype)
    up_ref[...] = upload
    err_ref[...] = (xf - upload.astype(jnp.float32)).astype(x.dtype)
    cnt_ref[0] = jnp.sum(mask.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("base", "interpret"))
def sparsify_quantize_ef(x: jax.Array, threshold, step, levels, seed,
                         base: int = 0, *, interpret: bool | None = None):
    """x: (n,) -> (quantised upload (n,), dequantised error (n,), count).

    Same blocking/padding as ``sparsify_ef``; upload/count match
    ``kernels.ref.sparsify_quantize_ef_ref`` bit-for-bit (shared dither;
    error up to one FMA rounding).  ``base`` offsets the dither counter
    for multi-leaf messages.
    """
    interpret = _resolve_interpret(interpret)
    n = x.size
    params = jnp.stack([
        jnp.asarray(threshold, jnp.float32),
        jnp.asarray(step, jnp.float32),
        jnp.asarray(levels, jnp.float32),
    ])
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1)
    per_block = LANE * BLOCK_R
    blocks = max((n + per_block - 1) // per_block, 1)
    padded = blocks * per_block
    xp = jnp.pad(x.reshape(-1), (0, padded - n)).reshape(blocks * BLOCK_R, LANE)
    up, err, cnt = pl.pallas_call(
        functools.partial(_kernel_q, base=int(base)),
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_R, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks * BLOCK_R, LANE), x.dtype),
            jax.ShapeDtypeStruct((blocks,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, params, seed_arr)
    count = jnp.sum(cnt)
    pad_elems = padded - n
    count = count - jnp.where(params[0] <= 0, float(pad_elems), 0.0)
    return up.reshape(-1)[:n], err.reshape(-1)[:n], count
