"""Jit'd dispatch wrappers for the Pallas kernels.

``impl`` selection:
  "auto"              Pallas compiled on TPU, pure-jnp reference elsewhere
                      (this container is CPU, so production dispatch falls
                      back to the oracle — the kernels are validated in
                      interpret mode by the test suite).
  "pallas"            pl.pallas_call compiled (TPU).
  "pallas_interpret"  kernel body executed in Python on CPU (tests).
  "ref"               pure-jnp oracle.
"""
from __future__ import annotations

import jax

from repro.kernels import ref as REF


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparsify_ef(x, threshold, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return REF.sparsify_ef_ref(x, threshold)
    from repro.kernels import sparsify_ef as K

    return K.sparsify_ef(x, threshold, interpret=(impl == "pallas_interpret"))


def sparsify_quantize_ef(x, threshold, step, levels, seed, base: int = 0,
                         *, impl: str = "auto"):
    """Fused sparsify + stochastic quantize + EF (compression codecs).

    Accepts any leaf shape; the Pallas path flattens internally.  The jnp
    oracle and the kernel share the counter-based dither of
    ``compression.quant``, so every impl returns identical values.
    """
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return REF.sparsify_quantize_ef_ref(x, threshold, step, levels, seed,
                                            base=base)
    from repro.kernels import sparsify_ef as K

    up, err, cnt = K.sparsify_quantize_ef(
        x.reshape(-1), threshold, step, levels, seed, base,
        interpret=(impl == "pallas_interpret"),
    )
    return up.reshape(x.shape), err.reshape(x.shape), cnt


def decode_attn(q, k, v, length, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return REF.decode_attn_ref(q, k, v, length)
    from repro.kernels import decode_attn as K

    return K.decode_attn(q, k, v, length, interpret=(impl == "pallas_interpret"))


def ssd_scan(x, a, b, c, *, chunk: int = 128, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        from repro.models.mamba2 import ssd_chunked

        return ssd_chunked(x, a, b, c, chunk)
    from repro.kernels import ssd_scan as K

    return K.ssd_scan(x, a, b, c, chunk=chunk, interpret=(impl == "pallas_interpret"))
