#!/usr/bin/env python3
"""Markdown link checker (stdlib-only, offline).

Scans every ``*.md`` file under the repo for ``[text](target)`` links and
verifies that relative targets exist on disk (anchors are stripped;
``http(s)://`` / ``mailto:`` targets are skipped — the container is
offline).  Used by CI and ``tests/test_docs.py`` so docs cross-references
(root README <-> subsystem READMEs) cannot rot silently.

    python tools/check_links.py [root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target until the first unescaped ')'; tolerates titles
LINK_RE = re.compile(r"\[[^\]]*\]\(\s*<?([^)>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "runs", "node_modules"}


def iter_md_files(root: pathlib.Path):
    for path in sorted(root.rglob("*.md")):
        if not SKIP_DIRS.intersection(p.name for p in path.parents):
            yield path


def check_file(md: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    in_code = False
    for lineno, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1).split("#", 1)[0]
            if not target or target.startswith(SKIP_PREFIXES):
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(root)}:{lineno}: broken link "
                    f"-> {m.group(1)}"
                )
    return errors


def main(root: str = ".") -> int:
    rootp = pathlib.Path(root).resolve()
    errors, checked = [], 0
    for md in iter_md_files(rootp):
        checked += 1
        errors.extend(check_file(md, rootp))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {checked} markdown files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
