#!/usr/bin/env python3
"""Compare two BENCH_<suite>.json exports and flag throughput regressions.

    python tools/bench_compare.py baseline/BENCH_afl.json current/BENCH_afl.json
    python tools/bench_compare.py baseline/BENCH_afl.json current/BENCH_afl.json \
        --check --threshold 0.30

Rows are matched by ``name``.  Higher-is-better metrics (``rounds_per_s``,
``tok_per_s``, anything ``*_per_s``) regress when current < baseline by
more than the threshold fraction; ``us_per_call`` (lower is better)
regresses when current > baseline by more than the threshold.  ``--check``
exits 1 on any regression (the CI gate); a missing baseline file exits 0
so fresh branches pass until a baseline lands.

Stdlib-only on purpose: runs in CI images without the repo's deps.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HIGHER_BETTER_SUFFIX = "_per_s"


def load_rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data.get("rows", [])}


def compare(base: dict, cur: dict, threshold: float) -> list[dict]:
    """Per-matched-row deltas; ``regressed`` marks threshold violations."""
    out = []
    for name, b in sorted(base.items()):
        c = cur.get(name)
        if c is None:
            continue
        checks = []
        b_us, c_us = b.get("us_per_call", 0.0), c.get("us_per_call", 0.0)
        if b_us > 0 and c_us > 0:
            checks.append(("us_per_call", b_us, c_us,
                           (c_us - b_us) / b_us))  # + = slower
        for key, bv in b.get("metrics", {}).items():
            cv = c.get("metrics", {}).get(key)
            if cv is None or not key.endswith(HIGHER_BETTER_SUFFIX) or bv <= 0:
                continue
            checks.append((key, bv, cv, (bv - cv) / bv))  # + = slower
        for key, bv, cv, slowdown in checks:
            out.append({
                "name": name, "metric": key, "baseline": bv, "current": cv,
                "slowdown": slowdown, "regressed": slowdown > threshold,
            })
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="BENCH_<suite>.json to compare against")
    ap.add_argument("current", help="freshly exported BENCH_<suite>.json")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="fractional slowdown that counts as a regression")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any regression (CI gate)")
    args = ap.parse_args()

    if not os.path.exists(args.baseline):
        print(f"bench_compare: no baseline at {args.baseline}; skipping")
        return 0
    if not os.path.exists(args.current):
        print(f"bench_compare: missing current file {args.current}")
        return 1

    deltas = compare(load_rows(args.baseline), load_rows(args.current),
                     args.threshold)
    if not deltas:
        print("bench_compare: no matching rows")
        return 0

    width = max(len(d["name"]) for d in deltas)
    regressed = [d for d in deltas if d["regressed"]]
    for d in deltas:
        mark = "REGRESSED" if d["regressed"] else "ok"
        print(f"{d['name']:<{width}s} {d['metric']:>14s} "
              f"base={d['baseline']:<12.4g} cur={d['current']:<12.4g} "
              f"slowdown={d['slowdown']:+7.1%} {mark}")
    print(f"bench_compare: {len(regressed)}/{len(deltas)} checks regressed "
          f"(threshold {args.threshold:.0%})")
    return 1 if (args.check and regressed) else 0


if __name__ == "__main__":
    sys.exit(main())
