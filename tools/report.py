#!/usr/bin/env python
"""Render a markdown run report from a telemetry.jsonl event stream.

    PYTHONPATH=src python tools/report.py runs/sweep/telemetry.jsonl \
        --bench bench-out/BENCH_afl.json --out runs/sweep/report.md

Sections (present when the events carry them): phase-time breakdown from
PhaseTracer spans, federation counters + ASCII histograms, per-group
results, the per-device straggler table, theory-vs-measured probe tables,
and the BENCH_* throughput trajectory.  CI runs this on the smoke-sweep
telemetry and uploads the report as a build artifact.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.telemetry import load_bench, read_jsonl, render_report  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(
        description="telemetry.jsonl (+ BENCH json) -> markdown run report")
    ap.add_argument("telemetry", help="path to a run's telemetry.jsonl")
    ap.add_argument("--bench", default="",
                    help="optional BENCH_<suite>.json trajectory file")
    ap.add_argument("--out", default="",
                    help="output path (default: report.md next to the input)")
    ap.add_argument("--title", default="Run report")
    args = ap.parse_args()

    events = read_jsonl(args.telemetry)
    bench = load_bench(args.bench) if args.bench else None
    text = render_report(events, bench=bench, title=args.title)

    out = args.out or os.path.join(
        os.path.dirname(os.path.abspath(args.telemetry)), "report.md")
    with open(out, "w") as f:
        f.write(text)
    print(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
