"""Sparsification operator semantics (paper §III-D)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsify as SP

RNG = np.random.default_rng(42)


def test_exact_topk_selects_largest():
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2, 4.0])
    up, err, k = SP.sparsify_topk(x, 3, method="exact")
    np.testing.assert_allclose(np.asarray(up), [0, -5.0, 0, 2.0, 0, 4.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(err), [0.1, 0, 0.3, 0, -0.2, 0], atol=1e-6)
    assert float(k) == 3


def test_upload_plus_error_reconstructs_x():
    x = jnp.asarray(RNG.normal(0, 1, 4096), jnp.float32)
    for k in [0, 1, 100, 4096]:
        up, err, _ = SP.sparsify_topk(x, k, method="exact")
        np.testing.assert_allclose(np.asarray(up + err), np.asarray(x))


def test_k_zero_uploads_nothing():
    x = jnp.asarray(RNG.normal(0, 1, 128), jnp.float32)
    up, err, k = SP.sparsify_topk(x, 0, method="exact")
    assert float(jnp.sum(jnp.abs(up))) == 0
    assert float(k) == 0


def test_error_norm_decreases_with_k():
    """Larger k => smaller sparsification error (Lemma 3 mechanism)."""
    x = jnp.asarray(RNG.normal(0, 1, 2048), jnp.float32)
    errs = []
    for k in [16, 64, 256, 1024, 2048]:
        _, err, _ = SP.sparsify_topk(x, k, method="exact")
        errs.append(float(jnp.sum(err**2)))
    assert all(a >= b for a, b in zip(errs, errs[1:]))
    assert errs[-1] == 0.0


def test_sampled_close_to_exact():
    x = jnp.asarray(RNG.normal(0, 1, 100_000), jnp.float32)
    k = 10_000
    _, _, k_exact = SP.sparsify_topk(x, k, method="exact")
    _, _, k_sampled = SP.sparsify_topk(x, k, method="sampled", sample=16384)
    assert abs(float(k_sampled) - k) / k < 0.1  # within 10%


def test_tree_sparsify_global_threshold():
    """One global threshold across leaves: big-magnitude leaf wins."""
    tree = {"a": jnp.full((100,), 0.01), "b": jnp.full((10,), 1.0)}
    up, err, k = SP.sparsify_tree(tree, 10, method="exact")
    assert float(jnp.sum(jnp.abs(up["a"]))) == 0.0
    np.testing.assert_allclose(np.asarray(up["b"]), 1.0)
    assert float(k) == 10


def test_bits_accounting():
    s, u = 2**20, 32
    bits = SP.bits_for_k(100.0, s, u)
    assert float(bits) == 100 * (32 + 20)
    k = SP.k_for_bits(float(bits), s, u)
    assert abs(float(k) - 100) < 1e-3


def test_sparsify_error_bounded_by_lemma3_shape():
    """E||x - S(x)||^2 <= (1 - k/s)-ish ||x||^2 (uniform-ish magnitudes)."""
    x = jnp.asarray(RNG.normal(0, 1, 8192), jnp.float32)
    k = 2048
    _, err, _ = SP.sparsify_topk(x, k, method="exact")
    # top-k always does at least as well as random-k:
    assert float(jnp.sum(err**2)) <= (1 - k / 8192) * float(jnp.sum(x**2)) + 1e-5


def test_sampled_threshold_agrees_across_shard_layouts():
    """The sharded-threshold contract (core/README.md): ``_strided_sample``
    draws a different strided subset for every leaf layout, so the sampled
    threshold moves between shard layouts — but only within the documented
    quantile standard error (std of the realised selection count is
    ~ sqrt(k s / m), the binomial error of the ~k m / s sample points above
    the cutoff; the same model behind ``Compressor.spend``'s backoff)."""
    n = 1 << 18
    flat = np.asarray(RNG.normal(0, 1, n), np.float32)
    k, m = 0.05 * n, 8192
    layouts = [
        flat,                      # 1-D (the concat view)
        flat.reshape(512, 512),    # square
        flat.reshape(1024, 256),   # tall: leading dim strided first
        flat.reshape(64, 64, 64),  # 3-D
        flat.reshape(256, 1024).T.copy(),  # transposed storage order
    ]
    se = np.sqrt(k * n / m)
    for a in layouts:
        t = SP.tree_threshold({"w": jnp.asarray(a)}, k, method="sampled",
                              sample=m)
        realised = float(np.sum(np.abs(flat) >= float(t)))
        assert abs(realised - k) <= 4 * se, (a.shape, realised, k, se)


def test_quantize_values_roundtrip_and_noop():
    x = jnp.asarray(RNG.normal(0, 2, 512), jnp.float32)
    same = SP.quantize_values(x, 32)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(x))
    q8 = SP.quantize_values(x, 8)
    err = float(jnp.max(jnp.abs(q8 - x)))
    amax = float(jnp.max(jnp.abs(x)))
    assert err <= amax / 127 + 1e-6  # one quantisation step
    tree = {"a": x, "b": x * 0.1}
    qt = SP.quantize_values(tree, 8)
    assert set(qt) == {"a", "b"}
