"""Streaming ingestion server (repro/serve): backpressure accounting,
wire-format exactness, the staleness-weight family, and the acceptance
parity — the fused batched decompress+aggregate producing *bit-identical*
global weights to sequentially applying the same uploads through
``afl_round``, for all four compression codecs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.wire import (decode_values, encode_upload,
                                    index_bits, pack_batch)
from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.afl import StalenessWeight, afl_init, afl_round
from repro.core.runner import build_provider, sample_budgets
from repro.experiments import DataShard
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.serve import ArrivalBuffer, IngestServer, make_fused_ingest
from repro.telemetry import serve_registry

CODEC_POLICIES = ("mads-topk", "mads-joint", "qsgd", "fixed-kb")
ROUNDS = 4


# ---------------------------------------------------------------------------
# Arrival buffer: backpressure invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["reject", "defer"])
def test_backpressure_never_drops_silently(policy):
    """Every offered upload lands in exactly one counter: the accounting
    identity received == accepted + rejected + deferred holds through an
    overload, and every failed offer returns False."""
    buf = ArrivalBuffer(capacity=3, policy=policy)
    outcomes = [buf.offer(i) for i in range(10)]
    assert outcomes == [True] * 3 + [False] * 7
    c = buf.counters()
    assert c["received"] == 10 and c["accepted"] == 3
    assert c["deferred" if policy == "defer" else "rejected"] == 7
    assert c["rejected"] + c["deferred"] == 7
    assert c["received"] == c["accepted"] + c["rejected"] + c["deferred"]
    buf.check_invariant()
    # draining restores capacity; accounting still closes
    assert buf.take(2) == [0, 1]
    assert buf.offer(10) is True
    buf.check_invariant()
    c = buf.counters()
    assert c["accepted"] == c["taken"] + c["depth"]


def test_buffer_validates_construction():
    with pytest.raises(ValueError):
        ArrivalBuffer(capacity=0)
    with pytest.raises(ValueError):
        ArrivalBuffer(capacity=4, policy="drop")


def test_server_counts_backpressure_in_registry():
    """Rejected/deferred uploads surface in the telemetry snapshot — the
    'never silent' contract end to end."""
    w = {"a": jnp.zeros((16,), jnp.float32)}
    srv = IngestServer(w, num_devices=4, batch=2, max_k=4,
                       queue_capacity=2, queue_policy="reject")
    ups = [encode_upload({"a": np.eye(16, dtype=np.float32)[i]}, device=i)
           for i in range(5)]
    admitted = [srv.submit(p) for p in ups]
    assert admitted == [True, True, False, False, False]
    srv.drain()
    snap = srv.snapshot()
    assert snap["counters"]["received"] == 5
    assert snap["counters"]["accepted"] == 2
    assert snap["counters"]["rejected"] == 3
    assert snap["counters"]["ingested"] == 2
    assert snap["gauges"]["queue_peak"] == 2
    assert snap["gauges"]["queue_depth"] == 0


# ---------------------------------------------------------------------------
# Wire format: round-trip exactness
# ---------------------------------------------------------------------------


def test_wire_grid_codes_roundtrip_bitwise():
    """b < 32: integer grid codes decode as codes * step — the codecs'
    exact float multiply, so the dense payload reproduces bitwise."""
    rng = np.random.default_rng(0)
    step = 7.3e-4
    q = rng.integers(-(2 ** 14), 2 ** 14, size=50).astype(np.int32)
    dense = np.zeros(512, np.float32)
    idx = np.sort(rng.choice(512, 50, replace=False))
    dense[idx] = q.astype(np.float32) * np.float32(step)
    p = encode_upload({"x": dense}, b=15, step=step)
    assert p.k == int(np.count_nonzero(dense))
    packed = pack_batch([p], s=512, max_k=64, batch=1)
    vals = decode_values(packed["codes"], packed["step"], packed["b"])
    out = np.zeros(512, np.float32)
    out[packed["coords"][0][: p.k]] = np.asarray(vals)[0][: p.k]
    np.testing.assert_array_equal(out.view(np.int32), dense.view(np.int32))


def test_wire_raw_f32_roundtrip_bitwise():
    """b == 32: raw bit patterns survive the int32 bitcast exactly
    (including denormals and negative zero)."""
    dense = np.zeros(64, np.float32)
    dense[[1, 7, 33]] = [1e-40, -0.0, 3.14159]
    dense[5] = np.float32(1.1)
    p = encode_upload(dense, b=32)
    assert p.k == 3  # -0.0 is not a nonzero coordinate
    packed = pack_batch([p], s=64, max_k=8, batch=1)
    vals = np.asarray(decode_values(packed["codes"], packed["step"],
                                    packed["b"]))
    out = np.zeros(64, np.float32)
    out[packed["coords"][0][: p.k]] = vals[0][: p.k]
    expect = dense.copy()
    expect[7] = 0.0  # -0.0 compares equal to zero -> never shipped
    np.testing.assert_array_equal(out.view(np.int32), expect.view(np.int32))


def test_wire_padding_is_dropped_and_limits_enforced():
    dense = np.zeros(32, np.float32)
    dense[:6] = 1.0
    with pytest.raises(ValueError):
        encode_upload(dense, max_k=4)
    p = encode_upload(dense, max_k=8)
    packed = pack_batch([p], s=32, max_k=8, batch=2, server_round=5)
    assert (packed["coords"][0][6:] == 32).all()  # pad coord = s
    assert packed["mask"].tolist() == [1.0, 0.0]
    with pytest.raises(ValueError):
        pack_batch([p, p, p], s=32, max_k=8, batch=2)
    assert p.bits == 6 * (32 + index_bits(32))


# ---------------------------------------------------------------------------
# Staleness family: monotonicity + degenerate equivalence
# ---------------------------------------------------------------------------


def test_staleness_monotone_and_bounded():
    dtau = jnp.arange(0.0, 65.0)
    for sw in (StalenessWeight(family="hinge", hinge_a=2.0, hinge_b=4.0),
               StalenessWeight(family="poly", poly_a=0.5)):
        s = np.asarray(sw.s(dtau))
        assert s[0] == 1.0
        assert np.all(np.diff(s) <= 0), sw  # non-increasing
        assert np.all((s > 0) & (s <= 1.0)), sw
    # hinge is exactly 1 inside the grace window, 1/(a (dtau-b)) beyond
    hw = StalenessWeight(family="hinge", hinge_a=2.0, hinge_b=4.0)
    assert np.asarray(hw.s(jnp.asarray([0.0, 4.0]))).tolist() == [1.0, 1.0]
    np.testing.assert_allclose(float(hw.s(9.0)), 1.0 / (2.0 * 5.0))


def test_staleness_degenerate_settings_equal_constant():
    """hinge with the grace window past every observed dtau, and poly at
    a = 0, both collapse to the constant family at the same alpha."""
    dtau = jnp.arange(0.0, 33.0)
    const = StalenessWeight(family="constant", alpha=0.25)
    hinge = StalenessWeight(family="hinge", alpha=0.25, hinge_b=64.0)
    poly = StalenessWeight(family="poly", alpha=0.25, poly_a=0.0)
    np.testing.assert_array_equal(np.asarray(const.weight(dtau)),
                                  np.asarray(hinge.weight(dtau)))
    np.testing.assert_array_equal(np.asarray(const.weight(dtau)),
                                  np.asarray(poly.weight(dtau)))
    assert not const.is_identity  # alpha != 1 still scales
    assert StalenessWeight().is_identity


def test_staleness_validates_family():
    with pytest.raises(ValueError):
        StalenessWeight(family="exp").s(1.0)


def test_fused_ingest_applies_staleness_weights():
    """weight_sum in the serve registry equals sum(alpha * s(dtau)) over
    the ingested uploads, and the aggregated model reflects the
    discount."""
    s = 32
    w = {"a": jnp.zeros((s,), jnp.float32)}
    sw = StalenessWeight(family="poly", alpha=0.5, poly_a=1.0)
    dense = np.zeros(s, np.float32)
    dense[3] = 4.0
    ups = [encode_upload({"a": dense}, device=i, rnd=-i) for i in range(3)]
    srv = IngestServer(w, num_devices=1, batch=4, max_k=4, staleness=sw)
    for p in ups:
        srv.submit(p)
    srv.step()
    snap = srv.snapshot()
    expect_w = 0.5 * np.asarray([1.0, 1.0 / 2.0, 1.0 / 3.0])
    np.testing.assert_allclose(snap["counters"]["weight_sum"],
                               expect_w.sum(), rtol=1e-6)
    np.testing.assert_allclose(float(srv.w["a"][3]),
                               -4.0 * expect_w.sum(), rtol=1e-6)


# ---------------------------------------------------------------------------
# Acceptance: fused batched ingest bit-identical to sequential afl_round
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=ROUNDS, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    return cfg, model, fl, shard


def _reference_rounds(federation, policy_name):
    """Drive afl_round with exposed uploads; returns (w0, w_final, rounds)
    where rounds is a list of per-round (uploads, okf, b, step) pulled to
    the host."""
    cfg, model, fl, shard = federation
    policy = dataclasses.replace(
        BL.ALL[policy_name](model.num_params(), fl), expose_uploads=True)
    provider = build_provider(fl, policy_name, None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    state = afl_init(model, cfg, fl, jax.random.key(0))
    w0 = jax.tree.map(lambda l: np.asarray(l), state.w)
    key = shard.seed_key(0)
    rounds = []
    for r in range(ROUNDS):
        batch = shard.traced_batch(key, r)
        z, t, h2 = provider.round(r)
        state, m = afl_round(
            state, batch, jnp.asarray(z, jnp.float32),
            jnp.asarray(t, jnp.float32), jnp.asarray(h2, jnp.float32),
            budgets, model=model, cfg=cfg, fl=fl, policy=policy)
        rounds.append({
            "upload": jax.tree.map(lambda l: np.asarray(l), m["upload"]),
            "okf": np.asarray(m["uploads"]),
            "b": np.asarray(m["b"], np.float64),
            "step": np.asarray(m["upload_step"], np.float64),
        })
    w_final = jax.tree.map(lambda l: np.asarray(l), state.w)
    return w0, w_final, rounds


@pytest.mark.parametrize("policy_name", CODEC_POLICIES)
def test_fused_ingest_bitwise_matches_afl_round(federation, policy_name):
    """The tentpole acceptance: encode every round's uploads to the wire,
    push them through the bounded queue + fused batched
    decompress+aggregate, and land EXACTLY the weights afl_round produced
    — per round and at the end, bit for bit."""
    cfg, model, fl, shard = federation
    n = fl.num_devices
    w0, w_ref, rounds = _reference_rounds(federation, policy_name)
    s = sum(l.size for l in jax.tree.leaves(w0))
    srv = IngestServer(
        jax.tree.map(jnp.asarray, w0), num_devices=n, batch=n, max_k=s,
        queue_capacity=n)
    shipped = 0.0
    for r, rec in enumerate(rounds):
        for i in range(n):
            # quantised codecs ship grid codes at the codec's (step, b);
            # b = 0 (withheld) and b = 32 rows ride the raw-f32 path
            b_i = rec["b"][i] if rec["b"][i] > 0 else 32.0
            p = encode_upload(
                jax.tree.map(lambda l: l[i], rec["upload"]),
                b=b_i, step=float(rec["step"][i]), device=i,
                ok=float(rec["okf"][i]))
            assert srv.submit(p)
            shipped += p.k * rec["okf"][i]
        assert srv.step() == n
        # intermediate parity: server weights == afl_round weights at r
    for a, b in zip(jax.tree.leaves(srv.w), jax.tree.leaves(w_ref)):
        np.testing.assert_array_equal(np.asarray(a), b,
                                      err_msg=policy_name)
    assert shipped > 0  # parity is not vacuous
    snap = srv.snapshot()
    assert snap["counters"]["ingested"] == np.sum(
        [rec["okf"].sum() for rec in rounds])
    srv.buffer.check_invariant()


def test_scatter_mode_matches_parity_mode():
    """The O(B*K) scatter kernel agrees with the parity kernel to float
    tolerance (bitwise whenever no two uploads share a coordinate)."""
    rng = np.random.default_rng(3)
    s, B, K = 256, 8, 16
    w = {"a": jnp.asarray(rng.standard_normal(s // 2), jnp.float32),
         "b": jnp.asarray(rng.standard_normal(s // 2), jnp.float32)}
    ups = []
    for i in range(B):
        dense = np.zeros(s, np.float32)
        dense[rng.choice(s, K, replace=False)] = \
            rng.standard_normal(K).astype(np.float32)
        ups.append(encode_upload({"a": dense[: s // 2],
                                  "b": dense[s // 2:]},
                                 device=i, rnd=-i))
    sw = StalenessWeight(family="hinge", alpha=0.7)
    packed = pack_batch(ups, s=s, max_k=K, batch=B)
    reg = serve_registry()
    outs = {}
    for mode in ("parity", "scatter"):
        ingest = make_fused_ingest(w, batch=B, max_k=K, num_devices=B,
                                   staleness=sw, registry=reg, mode=mode)
        outs[mode], tstate = ingest(w, packed, reg.init_state())
        assert float(tstate["counters"]["ingested"]) == B
    for a, b in zip(jax.tree.leaves(outs["parity"]),
                    jax.tree.leaves(outs["scatter"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_empty_step_is_identity():
    w = {"a": jnp.ones((8,), jnp.float32)}
    srv = IngestServer(w, num_devices=2, batch=2, max_k=2)
    assert srv.step() == 0 and srv.rnd == 0
    np.testing.assert_array_equal(np.asarray(srv.w["a"]), np.ones(8))


# ---------------------------------------------------------------------------
# launch/serve.py regression: no model monkeypatching for audio frames
# ---------------------------------------------------------------------------


def test_serve_frames_passthrough_does_not_mutate_model():
    """Audio (enc-dec) serving passes frames through serve() — the model
    instance keeps its original prefill, and two serve() calls on the
    same model behave identically (the monkeypatch double-wrapped)."""
    from repro.launch.serve import serve

    cfg = get_config("whisper-large-v3").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    frames = jnp.asarray(
        rng.normal(0, 0.02, (1, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    prefill_before = model.prefill
    toks1, _ = serve(cfg, model, params, prompts, gen=2, frames=frames)
    assert model.prefill is prefill_before  # instance not mutated
    toks2, _ = serve(cfg, model, params, prompts, gen=2, frames=frames)
    np.testing.assert_array_equal(np.asarray(toks1), np.asarray(toks2))
