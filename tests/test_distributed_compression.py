"""Parity suite: the pjit distributed AFL step vs the single-host engines,
with every repro/compression codec riding both.

The distributed step (core/distributed.py) invokes codecs through the SAME
``core.afl.compress_uploads`` call as ``afl_round``, with an identical PRNG
carry (``DistAflState.ckey``) — so its uploads must be *bit-identical* to
the single-host engines for the deterministic codecs (topk, joint) and for
qsgd too (the dither is counter-based, not stateful).  The fast tests pin
this round-by-round on one device; the slow tests re-run it on a mesh of 2
simulated host devices (``launch.mesh.force_host_device_count`` shim, in a
subprocess so the backend initialises with the forced count) and drive the
``--codec joint --per-layer --mesh 2`` sweep end to end.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core import mads as M
from repro.core.afl import afl_init, afl_round
from repro.core.distributed import (
    DistConfig,
    init_state,
    make_afl_train_step,
    run_afl_rounds,
)
from repro.core.runner import build_provider, run_afl, sample_budgets
from repro.experiments import DataShard
from repro.experiments.scan_engine import eval_points
from repro.launch.train import build_device_data
from repro.models.registry import build_model

CODEC_POLICIES = ("mads-topk", "mads-joint", "qsgd", "fixed-kb")
ROUNDS = 6
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=ROUNDS, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    return cfg, model, fl, shard, ev


def _dist_step(model, cfg, fl, policy):
    dcfg = DistConfig(
        num_clients=fl.num_devices, learning_rate=fl.learning_rate,
        rounds=fl.rounds, state_dtype="float32", upload_dtype="float32",
    )
    step = make_afl_train_step(model, cfg, dcfg, policy.controller,
                               compressor=policy.compressor)
    return dcfg, jax.jit(step)


def _flatten(batch):
    """(N, B, ...) stacked minibatch -> the (N*B, ...) global batch the
    distributed step re-splits identically."""
    return jax.tree.map(lambda v: v.reshape((-1,) + v.shape[2:]), batch)


def _run_dist(model, cfg, fl, policy_name, shard, rounds, seed=0):
    policy = BL.ALL[policy_name](model.num_params(), fl)
    dcfg, step = _dist_step(model, cfg, fl, policy)
    provider = build_provider(fl, policy_name, None, rounds, seed)
    budgets = sample_budgets(fl, seed)
    state = init_state(model, dcfg, jax.random.key(seed))
    key = shard.seed_key(seed)
    state, hist = run_afl_rounds(
        step, state, provider,
        lambda r: _flatten(shard.traced_batch(key, r)), budgets,
        rounds=rounds,
    )
    return state, hist


@pytest.mark.parametrize("policy_name", CODEC_POLICIES)
def test_dist_step_bitwise_matches_afl_round(federation, policy_name):
    """Round-by-round: identical inputs -> bit-identical uploads (equal
    bits/k/b metrics AND an exactly equal aggregated global model)."""
    cfg, model, fl, shard, ev = federation
    policy = BL.ALL[policy_name](model.num_params(), fl)
    dcfg, step = _dist_step(model, cfg, fl, policy)
    provider = build_provider(fl, policy_name, None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    ds = init_state(model, dcfg, jax.random.key(0))
    ss = afl_init(model, cfg, fl, jax.random.key(0))
    key = shard.seed_key(0)
    shipped = 0.0
    for r in range(4):
        batch = shard.traced_batch(key, r)
        z, t, h2 = provider.round(r)
        z = jnp.asarray(z, jnp.float32)
        t = jnp.asarray(t, jnp.float32)
        h2 = jnp.asarray(h2, jnp.float32)
        ds, md = step(ds, _flatten(batch), z, t, h2, budgets)
        ss, ms = afl_round(ss, batch, z, t, h2, budgets,
                           model=model, cfg=cfg, fl=fl, policy=policy)
        for kk in ("bits", "k", "b"):
            np.testing.assert_array_equal(
                np.asarray(md[kk]), np.asarray(ms[kk]),
                err_msg=f"{policy_name} r={r} {kk}")
        for a, b in zip(jax.tree.leaves(ds.w), jax.tree.leaves(ss.w)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        shipped += float(jnp.sum(md["bits"]))
    assert shipped > 0  # the parity is not vacuous


@pytest.mark.parametrize("per_layer", [False, True],
                         ids=["global-split", "per-layer"])
def test_dist_codec_bits_within_contact_budget(federation, per_layer):
    """Acceptance: in the distributed step, every upload's realised bits
    satisfy bits <= tau * A(p) — including under per-layer budgets."""
    import dataclasses

    cfg, model, fl, shard, ev = federation
    fl = dataclasses.replace(fl, per_layer_budget=per_layer)
    policy = BL.ALL["mads-joint"](model.num_params(), fl)
    ctl = policy.controller
    _, hist = _run_dist(model, cfg, fl, "mads-joint", shard, ROUNDS)
    provider = build_provider(fl, "mads-joint", None, ROUNDS, 0)
    total = 0.0
    for r, m in enumerate(hist):
        _, tau, h2 = provider.round(r)
        cap = np.asarray(tau, np.float64) * np.asarray(M.rate_bps(
            jnp.asarray(m["power"]), jnp.asarray(h2, jnp.float32),
            ctl.bandwidth, ctl.noise_w_hz))
        bits = np.asarray(m["bits"], np.float64)
        assert np.all(bits <= cap * (1 + 1e-5) + 1e-3), (r, bits, cap)
    total = sum(float(np.sum(np.asarray(m["bits"]))) for m in hist)
    assert total > 0  # something actually shipped


@pytest.mark.parametrize("policy_name", ("mads-topk", "mads-joint", "qsgd"))
def test_dist_history_matches_scan_engine(federation, policy_name):
    """theta_mean / bits_mean histories of the distributed rounds equal the
    scan engine's (same provider, same DataShard stream, same seed)."""
    cfg, model, fl, shard, ev = federation
    _, hist = _run_dist(model, cfg, fl, policy_name, shard, ROUNDS)
    scan = run_afl(model, cfg, fl, policy_name, shard, ev, rounds=ROUNDS,
                   eval_every=3, engine="scan")
    n = fl.num_devices
    pts = eval_points(ROUNDS, 3)
    assert scan.history["round"] == pts
    # aggregate the dist metrics exactly like the engines do (f32 sums)
    theta = np.float32(0.0)
    bits = np.float32(0.0)
    ups = np.float32(0.0)
    theta_mean, bits_mean = [], []
    for r, m in enumerate(hist):
        theta += np.float32(np.sum(np.asarray(m["theta"], np.float32)))
        bits += np.float32(np.sum(np.asarray(m["bits"], np.float32)))
        ups += np.float32(np.sum(np.asarray(m["success"], np.float32)))
        if (r + 1) in pts:
            theta_mean.append(theta / np.float32((r + 1) * n))
            bits_mean.append(bits / max(ups, np.float32(1.0)))
    np.testing.assert_allclose(theta_mean, scan.history["theta_mean"],
                               rtol=1e-6, err_msg=policy_name)
    np.testing.assert_allclose(bits_mean, scan.history["bits_mean"],
                               rtol=1e-6, err_msg=policy_name)
    assert bits_mean[-1] > 0


# ---------------------------------------------------------------------------
# 2 simulated host devices (subprocess: the forced count must precede
# backend initialisation)
# ---------------------------------------------------------------------------


MESH_SCRIPT = r"""
import jax, numpy as np
from repro.launch.mesh import force_host_device_count
force_host_device_count(2)
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.compression.base import strict_threshold
from repro.compression.quant import tree_amax
from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.distributed import (
    DistConfig, client_state_shardings, init_state, make_afl_train_step,
    run_afl_rounds,
)
from repro.core.runner import build_provider, sample_budgets
from repro.experiments import DataShard
from repro.launch.train import build_device_data
from repro.models.registry import build_model

assert jax.device_count() == 2, jax.devices()

# --- 1. shard_map threshold/amax agreement (the axis-aware contract) -----
rng = np.random.default_rng(0)
x = rng.normal(0, 1, 1 << 16).astype(np.float32)
mesh1d = Mesh(np.asarray(jax.devices()), ("data",))
k = 3000.0

def body(xl):
    t = strict_threshold(xl, k, method="sampled", sample=4096,
                         axis="data", s=x.size)
    return t[None], tree_amax(xl, axis="data")[None]

ts, ams = jax.jit(shard_map(
    body, mesh=mesh1d, in_specs=P("data"), out_specs=P("data")
))(jnp.asarray(x))
ts, ams = np.asarray(ts), np.asarray(ams)
assert ts[0] == ts[1], ts          # every device agrees on the threshold
assert ams[0] == ams[1] == np.abs(x).max(), ams  # ...and on amax (exact)
count = float(np.sum(np.abs(x) > ts[0]))
se = np.sqrt(k * x.size / 8192)    # documented quantile error model
assert abs(count - k) <= 4 * se, (count, k, se)

# --- 2. sharded vs single-host AFL rounds: bit-identical bits history ----
cfg = get_config("resnet9-cifar10").replace(d_model=4)
model = build_model(cfg)
ROUNDS = 3
fl = FLConfig(num_devices=4, rounds=ROUNDS, batch_size=8,
              learning_rate=0.02, mean_contact=6.0, mean_intercontact=30.0,
              energy_budget=(40.0, 80.0))
dev, _ = build_device_data(cfg, fl, train_n=160, eval_n=32, seed=0)
shard = DataShard(dev, fl.batch_size, seed=0)
key = shard.seed_key(0)
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 1), ("data", "model"))

def batch_fn(r):
    return jax.tree.map(lambda v: v.reshape((-1,) + v.shape[2:]),
                        shard.traced_batch(key, r))

def run(policy_name, fl, sharded):
    policy = BL.ALL[policy_name](model.num_params(), fl)
    dcfg = DistConfig(num_clients=fl.num_devices, rounds=ROUNDS,
                      learning_rate=fl.learning_rate, state_dtype="float32",
                      upload_dtype="float32")
    step = jax.jit(make_afl_train_step(model, cfg, dcfg, policy.controller,
                                       compressor=policy.compressor))
    state = init_state(model, dcfg, jax.random.key(0))
    if sharded:  # commit the client axis to the 2-device data axis
        state = jax.device_put(state, client_state_shardings(state, mesh))
    provider = build_provider(fl, policy_name, None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    _, hist = run_afl_rounds(step, state, provider, batch_fn, budgets,
                             rounds=ROUNDS)
    return np.stack([np.asarray(m["bits"]) for m in hist])

import dataclasses
for policy_name, flv in (
    ("mads-topk", fl),
    ("mads-joint", fl),
    ("mads-joint", dataclasses.replace(fl, per_layer_budget=True)),
    ("qsgd", fl),
    ("fixed-kb", fl),
):
    b1 = run(policy_name, flv, sharded=False)
    b2 = run(policy_name, flv, sharded=True)
    tag = policy_name + ("+pl" if flv.per_layer_budget else "")
    assert np.array_equal(b1, b2), (tag, b1, b2)
    print("PARITY", tag, "bits_total", float(b1.sum()))
print("MESH_OK")
"""


@pytest.mark.slow
def test_two_device_mesh_parity():
    """Mesh of 2 simulated host devices: the sharded step's realised bits
    are bit-identical to the single-host run for all four codecs (and the
    per-layer joint codec), and the axis-aware threshold/amax agree across
    shards."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_OK" in out.stdout


SWEEP_ARGS = [
    "--arch", "resnet9-cifar10", "--width", "4", "--codec", "joint",
    "--per-layer", "--mesh", "2", "--seeds", "2", "--rounds", "4",
    "--eval-every", "2", "--devices", "4", "--train-n", "160",
]


@pytest.mark.slow
def test_sweep_per_layer_mesh_resumable(tmp_path):
    """Acceptance: ``launch/sweep.py --codec joint --per-layer --mesh 2``
    completes and resumes (the per-upload bits <= tau*A invariant of the
    same codec/step is pinned by the fast tests above)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    cmd = [sys.executable, "-m", "repro.launch.sweep",
           *SWEEP_ARGS, "--out", str(tmp_path)]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "mads-joint" in out.stdout
    index = tmp_path / "results.jsonl"
    cells = [json.loads(l) for l in index.read_text().splitlines()]
    assert len(cells) == 2  # 1 policy x 1 speed x 2 seeds
    assert all(c["policy"] == "mads-joint" for c in cells)
    # resume: nothing re-runs, no duplicate index rows
    out2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1200)
    assert out2.returncode == 0, out2.stderr[-3000:]
    assert len(index.read_text().splitlines()) == 2
