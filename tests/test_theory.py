"""Theory oracle vs Monte-Carlo simulation (Lemmas 2-3, Corollary 1)."""
import numpy as np
import pytest

from repro.core import theory as T
from repro.mobility.contact import ContactProcess


def _mc_staleness_second_moment(c, lam, delta, rounds=4000, n=8, seed=0):
    proc = ContactProcess(n, c, lam, delta, seed=seed)
    zeta, _ = proc.sample_rounds(rounds)
    thetas = []
    kappa = np.zeros(n, int)
    for r in range(1, rounds + 1):
        theta = r - kappa
        up = zeta[r - 1] == 1
        thetas.append(theta[up])  # staleness at contact rounds
        kappa[up] = r
    th = np.concatenate(thetas).astype(float)
    return float(np.mean(th**2))


@pytest.mark.parametrize("c,lam", [(4.0, 40.0), (8.0, 100.0), (2.0, 20.0)])
def test_lemma2_bounds_monte_carlo(c, lam):
    """Lemma 2's Theta_n bounds the simulated staleness second moment up to
    one round of discretisation: the theory assigns staleness theta when the
    residual gap t is in [theta*delta, (theta+1)*delta), while the discrete
    simulation re-contacts one round later (ceil vs floor).  So we check
    MC <= (sqrt(Theta) + 1)^2 with 15% slack."""
    delta = 10.0
    bound = T.staleness_second_moment(c, lam, delta)
    mc = _mc_staleness_second_moment(c, lam, delta)
    assert mc <= (bound**0.5 + 1.0) ** 2 * 1.15, (mc, bound)


def test_lemma2_monotonic_in_intercontact():
    """Theta increases with lambda (longer gaps -> staler models)."""
    vals = [T.staleness_second_moment(4.0, lam, 10.0) for lam in (20, 80, 320)]
    assert vals[0] <= vals[1] <= vals[2]


def test_lemma2_monotonic_in_contact():
    """Theta decreases with c (formula; note the paper's Remark-2 prose has
    the direction swapped — see EXPERIMENTS.md)."""
    vals = [T.staleness_second_moment(c, 100.0, 10.0) for c in (1.0, 8.0, 64.0)]
    assert vals[0] >= vals[1] >= vals[2]


def test_gamma_increases_with_contact_and_rate():
    s = 6_568_650
    g1 = T.gamma(1e6, 2.0, s)
    g2 = T.gamma(1e6, 8.0, s)
    g3 = T.gamma(4e6, 8.0, s)
    assert g1 <= g2 <= g3 <= 1.0


def test_lemma3_literal_bound_is_loose_for_gamma_near_one():
    """FINDING (EXPERIMENTS.md §Paper-validation): with realistic rates,
    gamma ~ 1 - 1e-5 and (1-gamma)||x||^2 falls BELOW the realised top-k
    residual whenever the window can't carry the full model — the last
    inequality of Appendix D is loose in the wrong direction as gamma -> 1.
    The corrected expectation E[(s-k)/s]||x||^2 does bound the error."""
    import jax.numpy as jnp

    from repro.core import sparsify as SP

    rng = np.random.default_rng(0)
    s, u = 4096, 32
    rate, c = 2e4, 3.0  # window carries ~ tau*rate/44 ~ 1.4k of 4096 coords
    x = jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    x2 = float(jnp.sum(x**2))
    errs = []
    for _ in range(300):
        tau = rng.exponential(c)
        k = min(tau * rate / (u + np.log2(s)), s)
        _, err, _ = SP.sparsify_topk(x, float(k), method="exact")
        errs.append(float(jnp.sum(err**2)))
    literal = (1 - T.gamma(rate, c, s, u)) * x2
    corrected = T.expected_error_fraction(rate, c, s, u) * x2
    assert np.mean(errs) > literal  # documents the paper's loose step
    assert np.mean(errs) <= corrected * 1.10  # corrected bound holds
    # and top-k beats the uniform-mass assumption with margin on average
    assert np.mean(errs) <= corrected * 1.02


def test_corollary1_u_shape_model_gamma():
    """Remark 3: bound first decreases then increases in speed v (using the
    full-model gamma form; the literal per-element form only turns at
    ~1e5 m/s with Table-I constants — see EXPERIMENTS.md)."""
    args = dict(
        f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=20, rounds=500,
        rate=1e6, contact_const=200.0, intercontact_const=4000.0,
        delta=10.0, s=100_000, gamma_mode="model",
    )
    v_grid = np.linspace(1.0, 120, 240)
    vals = np.array([T.corollary1_bound(v, **args) for v in v_grid])
    vstar = v_grid[int(np.argmin(vals))]
    assert 1.0 < vstar < 120  # interior optimum
    assert vals[0] > vals.min() * 1.05  # decreasing at low speed
    assert vals[-1] > vals.min() * 1.05  # increasing at high speed


def test_corollary1_paper_form_monotonicities():
    """The literal Corollary-1 expression still falls with v at vehicular
    speeds (staleness relief dominates its tiny per-element penalty)."""
    args = dict(
        f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=20, rounds=500,
        rate=1e6, contact_const=40.0, intercontact_const=4000.0,
        delta=10.0, s=6_568_650,
    )
    lo = T.corollary1_bound(2.0, **args)
    hi = T.corollary1_bound(30.0, **args)
    assert hi < lo


def test_theorem2_decreases_with_contact_time():
    """Remark 2: increasing c improves (lowers) the bound."""
    common = dict(f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=20, rounds=500,
                  rate=1e6, lam=400.0, delta=10.0, s=6_568_650)
    b = [T.theorem2_rhs(c=c, **common) for c in (1.0, 4.0, 16.0)]
    assert b[0] >= b[1] >= b[2]


def test_theorem2_increases_with_intercontact_time():
    common = dict(f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=20, rounds=500,
                  rate=1e6, c=4.0, delta=10.0, s=6_568_650)
    b = [T.theorem2_rhs(lam=lam, **common) for lam in (100.0, 400.0, 1600.0)]
    assert b[0] <= b[1] <= b[2]
