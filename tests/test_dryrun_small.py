"""Multi-device lowering tests (subprocess: 8 host devices, test meshes).

The production dry-run (512 devices) is exercised by
``python -m repro.launch.dryrun``; here we prove in CI time that every step
kind lowers + compiles for each architecture family on a (2,2,2)
pod/data/model mesh, and that the mesh factory behaves.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax, json
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_step

mesh = make_test_mesh(multi_pod=True)
results = {}
cases = [
    ("llama3.2-3b", InputShape("train_4k", 128, 8, "train")),
    ("qwen2-moe-a2.7b", InputShape("prefill_32k", 256, 8, "prefill")),
    ("mamba2-2.7b", InputShape("decode_32k", 256, 8, "decode")),
    ("zamba2-7b", InputShape("long_500k", 2048, 1, "decode")),
    ("whisper-large-v3", InputShape("train_4k", 128, 8, "train")),
    ("qwen2-vl-72b", InputShape("decode_32k", 256, 8, "decode")),
]
for arch, sh in cases:
    cfg = get_config(arch).reduced()
    built = build_step(cfg, sh, mesh)
    with mesh:
        c = jax.jit(built["step"], in_shardings=built["in_shardings"]).lower(*built["args"]).compile()
    results[f"{arch}:{sh.name}"] = "ok"
print("RESULT " + json.dumps(results))
"""


@pytest.mark.slow
def test_all_families_lower_on_multipod_test_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=1200,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    results = json.loads(line[len("RESULT "):])
    assert len(results) == 6 and all(v == "ok" for v in results.values())


def test_mesh_factory_shapes():
    # importing mesh.py must not initialise devices; factories are functions
    from repro.launch import mesh as M

    import inspect

    assert inspect.isfunction(M.make_production_mesh)
    src = inspect.getsource(M)
    assert "make_mesh" in src


def test_dryrun_sets_device_count_before_imports():
    """The first statements of dryrun.py must force 512 host devices.
    (Checked textually — importing the module would mutate XLA_FLAGS.)"""
    import repro.launch as L

    path = os.path.join(os.path.dirname(L.__file__), "dryrun.py")
    head = open(path).read(400)
    assert head.splitlines()[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in head
