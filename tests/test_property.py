"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.compression.perlayer import (
    solve_kb_per_leaf,
    split_score,
    uniform_split,
)
from repro.core import sparsify as SP
from repro.core import theory as T
from repro.launch import roofline as RL
from repro.utils.tree import flatten_concat, unflatten_like

SET = dict(max_examples=30, deadline=None)


@settings(**SET)
@given(
    n=st.integers(8, 2000),
    k=st.floats(0, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparsify_partition_invariant(n, k, seed):
    """upload + error == x and non-overlapping supports, for any k."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    up, err, cnt = SP.sparsify_topk(x, k * n, method="exact")
    np.testing.assert_allclose(np.asarray(up + err), np.asarray(x), atol=1e-7)
    overlap = np.asarray((up != 0) & (err != 0))
    assert not overlap.any()
    assert 0 <= float(cnt) <= n


@settings(**SET)
@given(
    n=st.integers(8, 500),
    k1=st.floats(0, 0.5),
    k2=st.floats(0.5, 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparsify_error_monotone_in_k(n, k1, k2, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    _, e1, _ = SP.sparsify_topk(x, k1 * n, method="exact")
    _, e2, _ = SP.sparsify_topk(x, k2 * n, method="exact")
    assert float(jnp.sum(e2**2)) <= float(jnp.sum(e1**2)) + 1e-6


@settings(**SET)
@given(
    c=st.floats(0.5, 50),
    lam=st.floats(1, 2000),
    delta=st.floats(1, 60),
)
def test_staleness_bound_at_least_one(c, lam, delta):
    """Theta >= 1 always (Lemma 2), finite for lam > 0."""
    th = T.staleness_second_moment(c, lam, delta)
    assert th >= 1.0
    assert np.isfinite(th)


@settings(**SET)
@given(
    rate=st.floats(1e4, 1e8),
    c=st.floats(0.1, 100),
    s=st.integers(100, 10**9),
)
def test_gamma_in_unit_interval(rate, c, s):
    g = T.gamma(rate, c, s)
    gm = T.gamma_model(rate, c, s)
    assert 0.0 <= gm <= g <= 1.0


@settings(**SET)
@given(
    seeds=st.integers(0, 2**31 - 1),
    shapes=st.lists(st.integers(1, 7), min_size=1, max_size=4),
)
def test_flatten_unflatten_roundtrip(seeds, shapes):
    rng = np.random.default_rng(seeds)
    tree = {f"k{i}": jnp.asarray(rng.normal(0, 1, (s, 2)), jnp.float32)
            for i, s in enumerate(shapes)}
    flat = flatten_concat(tree)
    back = unflatten_like(flat, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))


@settings(**SET)
@given(
    g=st.integers(2, 512),
    nelem=st.integers(1, 10**6),
)
def test_roofline_collective_factors_positive(g, nelem):
    line = f"  %ar = bf16[{nelem}] all-reduce(%x), replica_groups=[{512//g},{g}]<=[512]"
    text = "ENTRY %main () -> bf16[1] {\n" + line + "\n}"
    stats = RL.parse_collectives(text, 512)
    assert stats.total_bytes >= 0
    expected = 2.0 * (g - 1) / g * nelem * 2
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"], expected)


B_GRID = tuple(range(2, 17))


@settings(**SET)
@given(
    budget=st.floats(0.0, 1e7),
    data=st.data(),
)
def test_per_leaf_budget_solver_respects_global_budget(budget, data):
    """For random leaf-size/energy profiles: the realised per-leaf bits
    (floored k, per-leaf fp32 scales included) never exceed the global
    budget, k stays in [0, s_l], and b is drawn from the grid."""
    nleaves = data.draw(st.integers(1, 6))
    sizes = tuple(data.draw(st.integers(1, 5000)) for _ in range(nleaves))
    energies = jnp.asarray(
        [data.draw(st.floats(0.0, 1e3)) for _ in range(nleaves)], jnp.float32
    )
    lam = 14
    k, b = solve_kb_per_leaf(jnp.float32(budget), sizes, energies, lam,
                             B_GRID)
    k, b = np.asarray(k, np.float64), np.asarray(b, np.float64)
    bits = np.sum(np.floor(k) * (b + lam) + 32.0 * (k > 0))
    # f32 arithmetic inside the solver: allow one ulp of the budget
    assert bits <= budget * (1 + 1e-6) + 1e-3, (bits, budget, sizes)
    assert np.all(k >= 0) and np.all(k <= np.asarray(sizes))
    assert all(float(bb) in B_GRID for bb in b)


@settings(**SET)
@given(
    budget=st.floats(0.0, 1e7),
    data=st.data(),
)
def test_per_leaf_split_never_scores_below_global(budget, data):
    """The water-filled split's retained-useful-energy score is >= the
    global single-(k, b) split's on every profile (the solver falls back
    to the uniform split whenever greedy would lose, so this is exact)."""
    nleaves = data.draw(st.integers(1, 6))
    sizes = tuple(data.draw(st.integers(1, 5000)) for _ in range(nleaves))
    energies = jnp.asarray(
        [data.draw(st.floats(0.0, 1e3)) for _ in range(nleaves)], jnp.float32
    )
    lam = 14
    sz = jnp.asarray(sizes, jnp.float32)
    k, b = solve_kb_per_leaf(jnp.float32(budget), sizes, energies, lam,
                             B_GRID)
    k_u, b_u = uniform_split(jnp.float32(budget), sizes, lam, B_GRID)
    per_layer = float(split_score(k, b, sz, energies))
    global_ = float(split_score(k_u, b_u, sz, energies))
    assert per_layer >= global_ - 1e-7, (per_layer, global_, sizes)


@settings(**SET)
@given(st.data())
def test_pspec_never_reuses_mesh_axis(data):
    from jax.sharding import Mesh

    from repro.sharding import rules as R

    names = ["embed", "heads", "kv_heads", "head_dim", "mlp", "vocab",
             "experts", "batch", "seq", None]
    ndim = data.draw(st.integers(1, 5))
    dims = tuple(data.draw(st.sampled_from(names)) for _ in range(ndim))
    shape = tuple(data.draw(st.sampled_from([1, 4, 16, 28, 60, 128])) for _ in range(ndim))
    devs = np.tile(np.array(jax.devices()[:1]), 8).reshape(2, 4)
    mesh = Mesh(devs, ("data", "model"))
    ps = R.logical_to_pspec(dims, shape, R.RULES_SERVE, mesh)
    used = []
    for entry in ps:
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        used.extend(axes)
    assert len(used) == len(set(used))
    # divisibility always respected
    axis_sizes = {"data": 2, "model": 4}
    for entry, size in zip(tuple(ps) + (None,) * ndim, shape):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([axis_sizes[a] for a in axes]))
        assert size % prod == 0
