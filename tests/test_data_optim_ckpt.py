"""Data pipeline, optimizers, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save, latest_step
from repro.data import (
    DeviceLoader,
    SyntheticCifar,
    SyntheticTokens,
    SyntheticTrajectories,
    dirichlet_partition,
    gamma_class_proportions,
)
from repro.optim import adamw, sgd, momentum, clip_by_global_norm
from repro.optim.optimizers import apply_updates


def test_dirichlet_rho_controls_concentration():
    labels = np.repeat(np.arange(10), 100)
    prior = np.full(10, 0.1)
    low = gamma_class_proportions(50, prior, rho=0.1, seed=0)
    high = gamma_class_proportions(50, prior, rho=100.0, seed=0)
    # entropy of per-device mixtures: low rho -> concentrated (low entropy)
    ent = lambda p: float(-(p * np.log(p + 1e-12)).sum(1).mean())
    assert ent(low) < ent(high)


def test_partition_sizes_equal():
    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_partition(labels, 20, rho=0.5, seed=1)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
    assert sum(sizes) <= len(labels) + 20  # wrap-around may duplicate a few


def test_device_loader_stacks_all():
    ds = SyntheticCifar()
    imgs, labels = ds.make_split(80, seed=2)
    parts = dirichlet_partition(labels, 4, rho=1.0)
    loader = DeviceLoader(
        [{"images": imgs[p], "labels": labels[p]} for p in parts], batch_size=5
    )
    b = loader.sample_all()
    assert b["images"].shape == (4, 5, 32, 32, 3)
    assert b["labels"].shape == (4, 5)


def test_synthetic_cifar_learnable_signal():
    """Templates + low noise => a nearest-template classifier is accurate."""
    ds = SyntheticCifar(noise=0.2)
    imgs, labels = ds.make_split(200, seed=3)
    flat = imgs.reshape(len(imgs), -1)
    temp = ds.templates.reshape(10, -1)
    pred = np.argmin(
        ((flat[:, None] - temp[None]) ** 2).sum(-1), axis=1
    )
    assert (pred == labels).mean() > 0.95


def test_trajectories_shapes_and_ade_scale():
    ds = SyntheticTrajectories()
    d = ds.make_split(16, seed=4)
    assert d["past"].shape == (16, 20, 2)
    assert d["future"].shape == (16, 30, 2)
    assert d["lanes"].shape == (16, 32, 2)
    # future positions are centred at last observed point
    assert np.abs(d["past"][:, -1]).max() < 1e-3


def test_markov_tokens_in_vocab():
    ds = SyntheticTokens(vocab_size=128)
    d = ds.make_split(4, 64, seed=5)
    assert d["tokens"].max() < 128 and d["tokens"].min() >= 0


def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    losses = []
    for _ in range(steps):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        losses.append(float(loss))
    return losses


def test_optimizers_descend_quadratic():
    for opt in (sgd(0.1), momentum(0.05), adamw(0.3)):
        losses = _quadratic_losses(opt)
        assert losses[-1] < 0.05 * losses[0]


def test_grad_clipping():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) == 200.0


def test_checkpoint_roundtrip():
    tree = {
        "layers": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "opt": [np.float32(3.0), {"m": np.ones(4, np.int32)}],
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, tree)
        assert latest_step(d) == 7
        back, step = restore(d)
        assert step == 7
        np.testing.assert_array_equal(back["layers"]["w"], tree["layers"]["w"])
        np.testing.assert_array_equal(back["opt"][1]["m"], tree["opt"][1]["m"])
        assert float(back["opt"][0]) == 3.0
