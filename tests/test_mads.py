"""MADS controller closed forms (paper §V, Propositions 1-2, eq. 8)."""
import jax.numpy as jnp
import numpy as np

from repro.core import mads as M
from repro.core.mads import MadsController

S = 1_000_000
U = 32
BW = 1e6
N0 = 10 ** (-174 / 10.0) / 1000.0


def mk(v=1e-4, pmax=0.2, unconstrained=False):
    return MadsController(s=S, u=U, bandwidth=BW, noise_w_hz=N0, p_max=pmax,
                          v_weight=v, energy_unconstrained=unconstrained)


def test_proposition1_k_tight():
    """k* = tau A(p) / (u + log2 s) — constraint (12b) tight."""
    p = jnp.asarray([0.1])
    h2 = jnp.asarray([1e-10])
    tau = jnp.asarray([5.0])
    k = M.mads_k(p, tau, h2, S, U, BW, N0)
    a = float(M.rate_bps(p, h2, BW, N0)[0])
    expect = min(5.0 * a / (U + np.ceil(np.log2(S))), S)
    assert abs(float(k[0]) - expect) < 1e-3


def test_power_increases_with_staleness():
    """Proposition 2: p* increases with theta (stale devices push harder)."""
    ctl = mk()
    one = jnp.ones(1)
    q = jnp.asarray([10.0])
    h2 = jnp.asarray([1e-9])
    tau = jnp.asarray([4.0])
    ps = [
        float(ctl.select(one, jnp.asarray([th]), 100.0 * one, q, tau, h2)[1][0])
        for th in (1.0, 5.0, 25.0)
    ]
    assert ps[0] <= ps[1] <= ps[2]


def test_power_clipped_to_pmax():
    ctl = mk(v=1.0)  # huge V -> wants max power
    one = jnp.ones(1)
    k, p, e = ctl.select(one, one * 50, one * 1e3, one * 1e-9, one * 4.0,
                         jnp.asarray([1e-9]))
    assert float(p[0]) <= 0.2 + 1e-9


def test_zero_queue_gives_max_feasible_power():
    """q=0 => energy cost-free this round => transmit at the cap."""
    ctl = mk()
    one = jnp.ones(1)
    k, p, e = ctl.select(one, one, one * 100.0, one * 0.0, one * 4.0,
                         jnp.asarray([1e-9]))
    cap = float(M.power_cap(one * 4.0, jnp.asarray([1e-9]), S, U, BW, N0, 0.2)[0])
    assert abs(float(p[0]) - cap) < 1e-6


def test_no_contact_no_power():
    ctl = mk()
    zero = jnp.zeros(1)
    one = jnp.ones(1)
    k, p, e = ctl.select(zero, one, one * 100.0, one * 1.0, one * 4.0, one * 1e-9)
    assert float(k[0]) == 0.0 and float(p[0]) == 0.0 and float(e[0]) == 0.0


def test_queue_update_eq8():
    ctl = mk()
    q = jnp.asarray([1.0, 0.0])
    energy = jnp.asarray([2.0, 0.0])
    budget = jnp.asarray([100.0, 100.0])
    q2 = ctl.queue_update(q, energy, budget, rounds=100)
    np.testing.assert_allclose(np.asarray(q2), [1.0 + 2.0 - 1.0, 0.0])


def test_k_increases_with_contact_time():
    """Closed form: k* grows with tau (more window -> more gradients)."""
    ctl = mk()
    one = jnp.ones(1)
    ks = [
        float(ctl.select(one, one, one * 100.0, one * 0.1, one * t, one * 1e-9)[0][0])
        for t in (1.0, 4.0, 16.0)
    ]
    assert ks[0] <= ks[1] <= ks[2]


def test_optimal_benchmark_ignores_queue():
    ctl = mk(unconstrained=True)
    one = jnp.ones(1)
    _, p_lo, _ = ctl.select(one, one, one * 1.0, one * 1e9, one * 4.0, one * 1e-9)
    _, p_hi, _ = ctl.select(one, one, one * 1.0, one * 0.0, one * 4.0, one * 1e-9)
    assert abs(float(p_lo[0]) - float(p_hi[0])) < 1e-9  # queue-independent
