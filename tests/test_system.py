"""End-to-end behaviour tests for the paper's system.

A small federation (tiny ResNet on synthetic CIFAR) is trained with MADS and
the §VI-B baselines; we assert the qualitative claims the paper makes:
training converges, MADS respects energy budgets, the optimal benchmark
spends the most energy, and sparsification enables uploads that full-model
transfers miss under short contacts.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
from repro.models.registry import build_model


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=8)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=60, batch_size=16, learning_rate=0.02,
        mean_contact=8.0, mean_intercontact=20.0,
        energy_budget=(40.0, 80.0),
    )
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(600, seed=11)
    parts = dirichlet_partition(labels, fl.num_devices, rho=100.0, seed=11)
    loader = DeviceLoader(
        [{"images": imgs[p], "labels": labels[p]} for p in parts], fl.batch_size
    )
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=12)))
    return cfg, model, fl, loader, ev


def test_mads_learns(federation):
    cfg, model, fl, loader, ev = federation
    res = run_afl(model, cfg, fl, "mads", loader, ev, rounds=60, eval_every=60)
    assert res.final_eval > 0.25  # well above 10% chance after 40 rounds


def test_energy_ordering_and_budget(federation):
    cfg, model, fl, loader, ev = federation
    r_mads = run_afl(model, cfg, fl, "mads", loader, ev, rounds=30, eval_every=30)
    r_opt = run_afl(model, cfg, fl, "optimal", loader, ev, rounds=30, eval_every=30)
    e_mads = r_mads.history["energy"][-1]
    e_opt = r_opt.history["energy"][-1]
    assert e_opt >= e_mads * 0.99  # unconstrained benchmark spends >= MADS
    budgets_hi = 80.0 * fl.num_devices
    assert e_mads <= budgets_hi * 2.0


def test_sparsification_enables_uploads_under_short_contacts(federation):
    cfg, model, fl, loader, ev = federation
    short = dataclasses.replace(fl, mean_contact=0.1, mean_intercontact=30.0)
    r_spar = run_afl(model, cfg, short, "afl-spar", loader, ev, rounds=25, eval_every=25)
    r_full = run_afl(model, cfg, short, "afl", loader, ev, rounds=25, eval_every=25)
    up_spar = r_spar.history["uploads"][-1]  # cumulative
    up_full = r_full.history["uploads"][-1]
    assert up_spar > up_full  # full-model uploads fail in 0.1 s windows


def test_metrics_well_formed(federation):
    cfg, model, fl, loader, ev = federation
    res = run_afl(model, cfg, fl, "mads", loader, ev, rounds=10, eval_every=5)
    h = res.history
    assert len(h["round"]) == 2
    assert all(np.isfinite(v) for v in h["eval"])
    assert all(v >= 0 for v in h["energy"])
