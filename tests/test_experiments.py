"""Compiled experiment engine: scan/loop equivalence, grids, seed-vmap."""
import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.runner import run_afl
from repro.data import DeviceLoader
from repro.experiments import (
    DataShard,
    ExperimentGrid,
    GridCell,
    ResultsStore,
    mean_ci,
    run_afl_scanned,
    run_seed_batch,
)
from repro.experiments.grid import engine_policy
from repro.experiments.scan_engine import eval_points
from repro.launch.train import build_device_data
from repro.models.registry import build_model

ROUNDS, EVERY = 8, 4


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=ROUNDS, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    return cfg, model, fl, dev, ev


def _assert_hist_close(a: dict, b: dict):
    assert a["round"] == b["round"]
    for k in a:
        np.testing.assert_allclose(
            np.asarray(a[k]), np.asarray(b[k]), rtol=2e-4, atol=1e-5,
            err_msg=f"history key {k!r} diverged",
        )


# ---------------------------------------------------------------------------
# scan-vs-loop metric equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["mads", "afl"])
def test_scanned_matches_loop(federation, policy):
    """Same seeds, same DeviceLoader draws: identical history (float tol)."""
    cfg, model, fl, dev, ev = federation
    loop = run_afl(model, cfg, fl, policy, DeviceLoader(dev, fl.batch_size, 0),
                   ev, rounds=ROUNDS, eval_every=EVERY)
    scan = run_afl_scanned(model, cfg, fl, policy,
                           DeviceLoader(dev, fl.batch_size, 0), ev,
                           rounds=ROUNDS, eval_every=EVERY)
    _assert_hist_close(loop.history, scan.history)


def test_runner_engine_delegation(federation):
    """run_afl(engine="scan") routes through the compiled engine."""
    cfg, model, fl, dev, ev = federation
    shard = DataShard(dev, fl.batch_size, seed=0)
    a = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                eval_every=EVERY, engine="scan")
    b = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                        eval_every=EVERY)
    _assert_hist_close(a.history, b.history)
    with pytest.raises(ValueError):
        run_afl(model, cfg, fl, "mads", shard, ev, engine="warp")


@pytest.mark.slow
def test_scanned_matches_loop_shard_long(federation):
    """Long-horizon equivalence through the in-scan DataShard sampler —
    the loop runner draws the identical fold_in(key, r) batches."""
    cfg, model, fl, dev, ev = federation
    shard = DataShard(dev, fl.batch_size, seed=0)
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=30,
                   eval_every=10, seed=3)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=30,
                           eval_every=10, seed=3)
    _assert_hist_close(loop.history, scan.history)


def test_theta_mean_accumulates(federation):
    """hist theta_mean is the cumulative staleness mean, not the last
    round's snapshot: with sparse contacts it must exceed the round-1
    value (staleness grows between contacts)."""
    cfg, model, fl, dev, ev = federation
    res = run_afl(model, cfg, fl, "mads", DeviceLoader(dev, fl.batch_size, 0),
                  ev, rounds=ROUNDS, eval_every=EVERY)
    tm = res.history["theta_mean"]
    assert all(t >= 1.0 for t in tm)  # theta starts at r - kappa >= 1
    assert tm[-1] >= tm[0]


# ---------------------------------------------------------------------------
# seed-axis vmap
# ---------------------------------------------------------------------------


def test_seed_vmap_matches_independent(federation):
    cfg, model, fl, dev, ev = federation
    shard = DataShard(dev, fl.batch_size, seed=0)
    batch = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                           rounds=ROUNDS, eval_every=EVERY)
    assert len(batch) == 2
    for res, seed in zip(batch, (0, 1)):
        ind = run_afl_scanned(model, cfg, fl, "mads", shard, ev,
                              rounds=ROUNDS, eval_every=EVERY, seed=seed)
        _assert_hist_close(ind.history, res.history)
    # different seeds actually ran different scenarios
    assert batch[0].history["uploads"] != batch[1].history["uploads"]


# ---------------------------------------------------------------------------
# grid + results store
# ---------------------------------------------------------------------------


def test_grid_cells_groups_and_engine_key():
    grid = ExperimentGrid(policies=("mads", "afl", "fedmobile"),
                          speeds=(5.0, 20.0), seeds=(0, 1, 2), rounds=10)
    assert grid.size() == 3 * 2 * 3 == len(grid.cells())
    groups = grid.groups()
    assert len(groups) == 6
    for policy, mobility, speed, dropout, cells in groups:
        assert [c.seed for c in cells] == [0, 1, 2]
        assert all(c.policy == policy and c.speed == speed for c in cells)
        assert dropout == 0.0  # default heterogeneity axis is collapsed
    # legacy store keys are unchanged while the dropout axis is collapsed
    assert groups[0][4][0].key.count("__d") == 0
    fl = grid.fl_for("rwp", 20.0)
    assert fl.mobility_model == "rwp" and fl.speed == 20.0
    # FedAsync and FedMobile share engine flags -> one compiled program
    s = 1000
    base = FLConfig()
    assert engine_policy(BL.ALL["afl"](s, base)) == engine_policy(
        BL.ALL["fedmobile"](s, base))
    assert engine_policy(BL.ALL["afl"](s, base)) != engine_policy(
        BL.ALL["mads"](s, base))
    with pytest.raises(KeyError):
        ExperimentGrid(policies=("nope",))


def test_results_store_resume(tmp_path):
    grid = ExperimentGrid(policies=("mads",), speeds=(5.0,), seeds=(0, 1),
                          rounds=4, eval_every=2)
    store = ResultsStore(str(tmp_path))
    cells = grid.cells()
    hist = {"round": [2, 4], "eval": [0.5, 0.7], "uploads": [1.0, 3.0],
            "k_mean": [10.0, 12.0], "energy": [1.0, 2.0],
            "theta_mean": [1.0, 1.5], "power_mean": [0.1, 0.1]}
    store.save(cells[0], hist, meta={"arch": "tiny"})
    # completed cell is skipped; the other seed is still pending
    assert store.done(cells[0]) and not store.done(cells[1])
    assert store.pending(cells) == [cells[1]]
    assert store.load(cells[0])["eval"] == [0.5, 0.7]
    agg = store.aggregate(grid)
    m, ci, n = agg[("mads", "exponential", 5.0, 0.0)]
    assert m == pytest.approx(0.7) and n == 1
    assert "mads" in store.table(grid)
    # jsonl index got one line
    assert len((tmp_path / "results.jsonl").read_text().splitlines()) == 1


def test_mean_ci():
    m, ci = mean_ci([1.0, 1.0, 1.0])
    assert m == 1.0 and ci == 0.0
    m, ci = mean_ci([0.0, 1.0])
    assert m == 0.5 and ci > 0
    assert mean_ci([2.0]) == (2.0, 0.0)


def test_eval_points():
    assert eval_points(8, 4) == [4, 8]
    assert eval_points(10, 4) == [4, 8, 10]
    assert eval_points(3, 20) == [3]
