"""Differential harness: JAX scenario engine vs the NumPy oracle.

Layered like the pipeline itself:

* kinematics  — statistical parity only (independent PRNG streams):
  bounds/speed/grid/dwell properties, inverse-speed contact law, and
  CI-band agreement of contact statistics with the oracle models;
* extraction  — exact parity: on a SHARED (steps, N) in-range matrix,
  ``contact_intervals_jax`` reproduces ``contact_intervals`` and
  ``rounds_from_in_range`` reproduces ``intervals_to_rounds`` cell by
  cell (bit-equal on integer step grids);
* theory      — contact rate / staleness from the JAX extractor on an
  exponential renewal mask land inside CI bands of the closed forms in
  ``core/theory.py``;
* heterogeneity — availability/latency/dropout gating vs the pure-Python
  reference, Markov stationarity, and the DeviceTable loss counters.

Property tests run twice where hypothesis is available: a deterministic
parametrized sweep always runs (CI has no hard hypothesis dependency),
and a ``@given`` fuzzing twin activates when the package is installed.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import FLConfig
from repro.mobility.contact import intervals_to_rounds
from repro.mobility.waypoint import measure_contact_stats
from repro.scenarios import (
    JAX_MODELS,
    GaussMarkovModel,
    HeterogeneityModel,
    HotspotClusterModel,
    JaxGaussMarkovModel,
    JaxHotspotClusterModel,
    JaxManhattanGridModel,
    JaxRandomWaypointModel,
    ManhattanGridModel,
    RandomWaypointModel,
    ScenarioProvider,
    contact_intervals,
    contact_intervals_jax,
    gate_windows,
    jax_schedule_from_model,
    rounds_from_in_range,
)
from repro.scenarios.heterogeneity import reference_apply
from repro.scenarios.jax_kinematics import _reflect

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # optional dep: the parametrized twins still run
    HAVE_HYPOTHESIS = False

JAX_MODEL_CASES = [
    (JaxRandomWaypointModel, dict(pause_max=2.0)),
    (JaxGaussMarkovModel, {}),
    (JaxManhattanGridModel, {}),
    (JaxHotspotClusterModel, dict(hotspot_radius=250.0)),
]
_ids = lambda x: getattr(x, "__name__", "")


def random_masks(seed: int, steps: int, n: int, densities=(0.05, 0.3, 0.7)):
    """Correlated random in-range matrices (runs, not salt-and-pepper)."""
    rng = np.random.default_rng(seed)
    for p in densities:
        # threshold a random walk: produces contact runs of varied length
        walk = np.cumsum(rng.normal(0, 1, (steps, n)), axis=0)
        walk -= walk.mean(0)
        yield walk < np.quantile(walk, p, axis=0)


# ---------------------------------------------------------------------------
# kinematics: shape / bound / structure properties (deterministic sweep)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls,extra", JAX_MODEL_CASES, ids=_ids)
def test_jax_trace_shapes_and_bounds(cls, extra):
    m = cls(num_devices=6, area=500.0, mean_speed=8.0, seed=3, **extra)
    tr = m.trace(200.0, 1.0)
    assert tr.pos.shape == (200, 6, 2)
    assert tr.mes.shape == (200, 2)
    pos = np.asarray(tr.pos)
    assert np.isfinite(pos).all()
    assert pos.min() >= -1e-3 and pos.max() <= 500.0 + 1e-3
    assert np.asarray(tr.in_range(100.0)).dtype == bool


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_jax_rwp_speed_bounds(seed):
    """Per-leg speeds are U(0.5v, 1.5v): no step may exceed 1.5 v dt."""
    v = 12.0
    m = JaxRandomWaypointModel(num_devices=16, area=400.0, mean_speed=v,
                               pause_max=3.0, seed=seed)
    pos = np.asarray(m.trace(300.0, 1.0).pos)
    step = np.linalg.norm(np.diff(pos, axis=0), axis=-1)
    assert step.max() <= 1.5 * v + 1e-3


def test_jax_manhattan_grid_snap_and_speed():
    m = JaxManhattanGridModel(num_devices=8, area=600.0, mean_speed=10.0,
                              block=100.0, seed=5)
    pos = np.asarray(m.trace(500.0, 1.0).pos)
    # at any instant one coordinate sits on a grid line (multiple of block)
    frac = np.abs(pos / 100.0 - np.round(pos / 100.0))
    assert (frac.min(axis=-1) < 1e-3).all()
    step = np.linalg.norm(np.diff(pos, axis=0), axis=-1)
    assert step.max() <= 1.5 * 10.0 + 1e-3


def test_jax_hotspot_static_at_zero_speed():
    m = JaxHotspotClusterModel(num_devices=5, mean_speed=0.0, seed=2)
    pos = np.asarray(m.trace(50.0, 1.0).pos)
    assert np.all(pos == pos[0])


def test_jax_hotspot_dwell():
    """Devices orbit their anchor: excursions stay O(radius), far below the
    area scale, and the time-averaged position is near the anchor."""
    radius = 100.0
    m = JaxHotspotClusterModel(num_devices=24, area=2000.0, mean_speed=5.0,
                               num_hotspots=3, hotspot_radius=radius, seed=7)
    pos = np.asarray(m.trace(800.0, 1.0).pos)  # (steps, n, 2)
    center = pos.mean(axis=0)  # per-device dwell point ~ anchor
    excur = np.linalg.norm(pos - center[None], axis=-1)
    assert np.quantile(excur, 0.95) < 5 * radius  # OU keeps devices close
    assert excur.max() < 0.5 * 2000.0  # never wanders across the area


def test_reflect_bounds_parametrized():
    x = np.linspace(-3000.0, 3000.0, 4001, dtype=np.float32)
    y = np.asarray(_reflect(jnp.asarray(x), 500.0))
    assert (y >= 0).all() and (y <= 500.0).all()
    # in-domain points are fixed points of the fold
    inside = (x >= 0) & (x <= 500.0)
    np.testing.assert_allclose(y[inside], x[inside], atol=1e-3)


# ---------------------------------------------------------------------------
# kinematics: hypothesis fuzzing twins (skipped when not installed)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @given(x=st.floats(-1e6, 1e6), hi=st.floats(1.0, 1e4))
    @settings(max_examples=200, deadline=None)
    def test_reflect_bounds_hypothesis(x, hi):
        y = float(_reflect(jnp.float32(x), float(hi)))
        assert -1e-2 <= y <= hi + 1e-2

    @given(seed=st.integers(0, 2**31 - 1), v=st.floats(0.5, 40.0),
           area=st.floats(100.0, 2000.0))
    @settings(max_examples=10, deadline=None)
    def test_rwp_trace_bounds_hypothesis(seed, v, area):
        m = JaxRandomWaypointModel(num_devices=4, area=area, mean_speed=v,
                                   seed=seed)
        pos = np.asarray(m.trace(100.0, 1.0).pos)
        assert pos.min() >= -1e-2 and pos.max() <= area + 1e-2
        step = np.linalg.norm(np.diff(pos, axis=0), axis=-1)
        assert step.max() <= 1.5 * v + 1e-2

    @given(seed=st.integers(0, 2**31 - 1),
           block=st.sampled_from([50.0, 100.0, 150.0]))
    @settings(max_examples=10, deadline=None)
    def test_manhattan_snap_hypothesis(seed, block):
        m = JaxManhattanGridModel(num_devices=4, area=600.0, mean_speed=10.0,
                                  block=block, seed=seed)
        pos = np.asarray(m.trace(120.0, 1.0).pos)
        frac = np.abs(pos / block - np.round(pos / block))
        assert (frac.min(axis=-1) < 1e-3).all()


# ---------------------------------------------------------------------------
# kinematics: statistical parity with the NumPy oracle
# ---------------------------------------------------------------------------


ORACLE_OF = {
    JaxRandomWaypointModel: RandomWaypointModel,
    JaxGaussMarkovModel: GaussMarkovModel,
    JaxManhattanGridModel: ManhattanGridModel,
    JaxHotspotClusterModel: HotspotClusterModel,
}


@pytest.mark.parametrize("cls,extra", JAX_MODEL_CASES, ids=_ids)
def test_jax_contact_stats_match_oracle(cls, extra):
    """Independent PRNGs: mean contact / intercontact agree within a 2x
    band per model (the same tolerance class as the oracle's own
    vectorized-vs-seed-loop test)."""
    kw = dict(num_devices=40, area=600.0, mean_speed=9.0, **extra)
    jm = cls(seed=11, **kw)
    om = ORACLE_OF[cls](seed=12, **{k: v for k, v in kw.items()})
    c_j, g_j = measure_contact_stats(
        np.asarray(jm.trace(3000.0, 1.0).in_range(100.0)))
    c_o, g_o = measure_contact_stats(om.trace(3000.0, 1.0).in_range(100.0))
    assert c_j > 0 and np.isfinite(g_j)
    assert 0.5 < c_j / c_o < 2.0, (c_j, c_o)
    assert 0.5 < g_j / g_o < 2.0, (g_j, g_o)


def test_jax_inverse_speed_law_large_n():
    """Corollary 1's c ~ C/v, lam ~ L/v on the JAX path at N=1e4: the
    fleet-sized trace gives tight contact statistics from a short horizon."""
    stats = []
    for v, seed in ((3.0, 7), (12.0, 8)):
        m = JaxGaussMarkovModel(num_devices=10_000, area=600.0, mean_speed=v,
                                seed=seed)
        ir = np.asarray(m.trace(2000.0, 1.0).in_range(100.0))
        stats.append(measure_contact_stats(ir))
    (c_slow, g_slow), (c_fast, g_fast) = stats
    assert c_fast > 0 and np.isfinite(g_fast)
    # speeds differ 4x; N=1e4 shrinks the CI, so a tighter band than the
    # oracle's N=48 test is safe
    assert 2.6 < c_slow / c_fast < 6.1, (c_slow, c_fast)
    assert 2.6 < g_slow / g_fast < 6.1, (g_slow, g_fast)


# ---------------------------------------------------------------------------
# extraction: exact parity on shared in-range matrices
# ---------------------------------------------------------------------------


def test_intervals_exact_on_shared_masks():
    for mask in random_masks(0, steps=400, n=17):
        dev_o, start_o, dur_o = contact_intervals(mask, dt=2.0)
        dev_j, start_j, dur_j = contact_intervals_jax(mask, dt=2.0)
        np.testing.assert_array_equal(np.asarray(dev_j), dev_o)
        np.testing.assert_array_equal(np.asarray(start_j), start_o)
        np.testing.assert_array_equal(np.asarray(dur_j), dur_o)


def test_intervals_static_size_padding():
    mask = next(iter(random_masks(1, steps=200, n=5, densities=(0.3,))))
    dev_o, start_o, dur_o = contact_intervals(mask, dt=1.0)
    k = len(dev_o)
    dev_j, start_j, dur_j = contact_intervals_jax(mask, dt=1.0, size=k + 7)
    assert dev_j.shape == (k + 7,)
    np.testing.assert_array_equal(np.asarray(dev_j[:k]), dev_o)
    np.testing.assert_array_equal(np.asarray(start_j[:k]), start_o)
    np.testing.assert_array_equal(np.asarray(dur_j[:k]), dur_o)
    assert (np.asarray(dev_j[k:]) == -1).all()
    assert (np.asarray(dur_j[k:]) == 0).all()


def _oracle_rounds(mask, dt, rounds, delta, drop_truncated=False):
    dev, start, dur = contact_intervals(mask, dt=dt)
    if drop_truncated:
        steps = mask.shape[0]
        keep = start + dur < steps * dt - 1e-9  # run ends before the horizon
        dev, start, dur = dev[keep], start[keep], dur[keep]
    return intervals_to_rounds(dev, start, dur, mask.shape[1], rounds, delta)


def test_rounds_exact_on_integer_grid():
    """dt=1, delta=10: every boundary is an exact f32 integer, so the JAX
    extractor must be bit-equal to the interval oracle, cell by cell."""
    for mask in random_masks(2, steps=400, n=13):
        z_o, t_o = _oracle_rounds(mask, 1.0, 40, 10.0)
        z_j, t_j = rounds_from_in_range(mask, 1.0, 40, 10.0)
        np.testing.assert_array_equal(np.asarray(z_j), z_o)
        np.testing.assert_array_equal(np.asarray(t_j), t_o)


def test_rounds_on_noninteger_grid():
    """Fractional delta/dt ratio: zeta stays exact (same overlap logic),
    tau matches to f32 arithmetic tolerance."""
    dt, delta, rounds = 0.5, 3.3, 55
    for mask in random_masks(3, steps=380, n=9):
        z_o, t_o = _oracle_rounds(mask, dt, rounds, delta)
        z_j, t_j = rounds_from_in_range(mask, dt, rounds, delta)
        np.testing.assert_array_equal(np.asarray(z_j), z_o)
        np.testing.assert_allclose(np.asarray(t_j), t_o, atol=1e-3)


@pytest.mark.parametrize("cls,extra", JAX_MODEL_CASES, ids=_ids)
def test_rounds_exact_on_real_jax_traces(cls, extra):
    """The headline differential: a real JAX trace's in-range matrix pushed
    through both extractors gives identical (zeta, tau) schedules."""
    m = cls(num_devices=24, area=500.0, mean_speed=10.0, seed=9, **extra)
    mask = np.asarray(m.trace(600.0, 1.0).in_range(100.0))
    z_o, t_o = _oracle_rounds(mask, 1.0, 60, 10.0)
    z_j, t_j = rounds_from_in_range(mask, 1.0, 60, 10.0)
    np.testing.assert_array_equal(np.asarray(z_j), z_o)
    np.testing.assert_array_equal(np.asarray(t_j), t_o)
    assert z_o.sum() > 0, "degenerate scenario: no contacts to compare"


def test_drop_truncated_regression():
    """The PR-1 window-bias fix, mirrored at the extractor level: contacts
    still open at the trace end must not contribute biased (low) tau."""
    # device 0: interior contact + one cut by the horizon; device 1: clean
    mask = np.zeros((100, 2), bool)
    mask[12:30, 0] = True   # interior: 18 s
    mask[85:, 0] = True     # truncated: 15 s observed, real length unknown
    mask[40:58, 1] = True
    z_keep, t_keep = rounds_from_in_range(mask, 1.0, 10, 10.0)
    z_drop, t_drop = rounds_from_in_range(mask, 1.0, 10, 10.0,
                                          drop_truncated=True)
    # exact cross-check against the oracle with host-side interval filtering
    z_o, t_o = _oracle_rounds(mask, 1.0, 10, 10.0, drop_truncated=True)
    np.testing.assert_array_equal(np.asarray(z_drop), z_o)
    np.testing.assert_array_equal(np.asarray(t_drop), t_o)
    # the censored cells disappear, everything else is untouched
    z_keep, t_keep = np.asarray(z_keep), np.asarray(t_keep)
    z_drop, t_drop = np.asarray(z_drop), np.asarray(t_drop)
    assert z_keep[8, 0] == 1 and z_drop[8, 0] == 0  # round 8 = steps 80..89
    assert z_keep.sum() - z_drop.sum() == 2  # rounds 8 and 9 of device 0
    np.testing.assert_array_equal(z_drop[:, 1], z_keep[:, 1])
    # censoring-in-place under-states the window (15 < 18): dropping the
    # truncated run removes the biased-low tau samples
    kept_tau = t_keep[(z_keep == 1) & (z_drop == 0)]
    assert kept_tau.max() <= 15.0
    assert t_drop[np.asarray(z_drop) == 1].min() > 0


def test_schedule_pipeline_is_jittable_end_to_end():
    """Zero mid-trace host syncs: the whole trace->schedule pipeline must
    trace under an OUTER jit (any host materialisation of a traced array
    would raise a ConcretizationTypeError)."""
    from repro.scenarios.jax_kinematics import _schedule

    model = JaxGaussMarkovModel(num_devices=8, area=400.0, seed=0)
    outer = jax.jit(lambda k: _schedule(model, k, 20, 10.0, 1.0, 100.0,
                                        25.0, 3.5, False))
    zeta, tau, h2 = outer(jax.random.key(0))
    assert isinstance(zeta, jax.Array) and isinstance(h2, jax.Array)
    assert zeta.shape == tau.shape == h2.shape == (20, 8)
    z, t = np.asarray(zeta), np.asarray(tau)
    assert ((t > 0) == (z == 1)).all()
    assert np.isfinite(np.asarray(h2)).all() and (np.asarray(h2) > 0).all()


# ---------------------------------------------------------------------------
# channel gains (statistical twins of the oracle tests)
# ---------------------------------------------------------------------------


def test_jax_gains_static_devices_see_constant_channel():
    from repro.scenarios import jax_gains_along_trace

    pos = jnp.broadcast_to(jnp.asarray([[30.0, 0.0], [80.0, 0.0]]),
                           (50, 2, 2))
    mes = jnp.zeros((50, 2))
    h2 = np.asarray(jax_gains_along_trace(jax.random.key(3), pos, mes))
    # zero displacement -> shadowing and LOS state frozen -> constant gain
    np.testing.assert_allclose(h2, np.broadcast_to(h2[0], h2.shape),
                               rtol=1e-5)


def test_jax_gains_decrease_with_distance():
    from repro.scenarios import jax_gains_along_trace

    pos = jnp.broadcast_to(jnp.asarray([[15.0, 0.0], [90.0, 0.0]]),
                           (5, 2, 2))
    h2 = np.asarray(jax_gains_along_trace(
        jax.random.key(0), pos, jnp.zeros((5, 2)),
        shadow_los_db=0.0, shadow_nlos_db=0.0))
    assert (h2[:, 0] > h2[:, 1]).all()


# ---------------------------------------------------------------------------
# theory: extractor statistics vs core/theory.py closed forms
# ---------------------------------------------------------------------------


def _exp_onoff_mask(steps, n, c, lam, dt, seed):
    """Stationary exponential alternating-renewal ON/OFF mask — the
    contact process Lemma 2's closed forms are derived for."""
    rng = np.random.default_rng(seed)
    horizon = steps * dt
    mask = np.zeros((steps, n), bool)
    t_grid = np.arange(steps) * dt
    for i in range(n):
        # memorylessness: a stationary start is an Exp residual phase
        t, on = 0.0, rng.random() < c / (c + lam)
        while t < horizon:
            dur = rng.exponential(c if on else lam)
            if on:
                mask[(t_grid >= t) & (t_grid < t + dur), i] = True
            t, on = t + dur, not on
    return mask


def test_contact_rate_and_times_in_theory_bands():
    """Measured contact rate / mean contact & intercontact times from the
    JAX extractor sit inside CI bands of the renewal closed forms."""
    c, lam, dt, delta = 8.0, 40.0, 1.0, 10.0
    steps, n, rounds = 5000, 128, 500
    mask = _exp_onoff_mask(steps, n, c, lam, dt, seed=0)
    c_meas, g_meas = measure_contact_stats(mask, dt=dt)
    assert abs(c_meas - c) / c < 0.08
    assert abs(g_meas - lam) / lam < 0.08
    zeta, _ = rounds_from_in_range(mask, dt, rounds, delta)
    # stationary renewal: P(round has contact) = 1 - P(off at the round
    # start) P(residual off > delta) = 1 - lam/(c+lam) e^{-delta/lam} —
    # the same alternating-renewal algebra behind staleness_second_moment
    p_theory = 1.0 - lam / (c + lam) * np.exp(-delta / lam)
    p_meas = float(np.asarray(zeta).mean())
    assert abs(p_meas - p_theory) / p_theory < 0.07, (p_meas, p_theory)


def test_staleness_second_moment_bound_holds():
    """Lemma 2 (core/theory.staleness_second_moment) upper-bounds the
    measured staleness second moment of the JAX-extracted schedule."""
    from repro.core.theory import staleness_second_moment

    c, lam, dt, delta = 8.0, 40.0, 1.0, 10.0
    steps, n, rounds = 5000, 128, 500
    mask = _exp_onoff_mask(steps, n, c, lam, dt, seed=1)
    zeta = np.asarray(rounds_from_in_range(mask, dt, rounds, delta)[0])
    gaps = []
    for i in range(n):
        hits = np.nonzero(zeta[:, i])[0]
        gaps.extend(np.diff(hits))
    gaps = np.asarray(gaps, np.float64)
    assert gaps.size > 1000
    theta2 = float((gaps**2).mean())
    bound = staleness_second_moment(c, lam, delta)
    assert theta2 <= bound * 1.1, (theta2, bound)
    assert theta2 >= bound * 0.05  # the bound is meaningful, not vacuous


# ---------------------------------------------------------------------------
# heterogeneity: gating, stationarity, DeviceTable counters
# ---------------------------------------------------------------------------


def test_het_gating_matches_python_reference():
    rng = np.random.default_rng(0)
    rounds, n = 60, 12
    zeta = (rng.random((rounds, n)) < 0.5).astype(np.int32)
    tau = np.where(zeta, rng.exponential(8.0, (rounds, n)), 0.0) \
        .astype(np.float32)
    avail = rng.random((rounds, n)) < 0.7
    latency = rng.exponential(2.0, (rounds, n)).astype(np.float32)
    drop = rng.random((rounds, n)) < 0.25
    z_v, t_v, a_v = gate_windows(zeta, tau, avail, latency, drop)
    z_r, t_r, a_r = reference_apply(zeta, tau, avail, latency, drop)
    np.testing.assert_array_equal(z_v, z_r)
    np.testing.assert_array_equal(t_v, t_r)
    for k in ("unavail", "dropout"):
        np.testing.assert_array_equal(a_v[k], a_r[k])
    # identical draws through jnp operands: same cells exactly
    z_d, t_d, a_d = gate_windows(jnp.asarray(zeta), jnp.asarray(tau),
                                 jnp.asarray(avail), jnp.asarray(latency),
                                 jnp.asarray(drop))
    np.testing.assert_array_equal(np.asarray(z_d), z_r)
    np.testing.assert_array_equal(np.asarray(t_d), t_r)
    for k in ("unavail", "dropout"):
        np.testing.assert_array_equal(np.asarray(a_d[k]), a_r[k])


def test_het_loss_causes_are_exclusive():
    """Every pre-gate contact resolves to exactly one outcome: success,
    unavailable, dropout, or latency-eaten (first cause wins)."""
    rng = np.random.default_rng(1)
    rounds, n = 80, 16
    zeta = (rng.random((rounds, n)) < 0.6).astype(np.int32)
    tau = np.where(zeta, rng.exponential(5.0, (rounds, n)), 0.0) \
        .astype(np.float32)
    avail = rng.random((rounds, n)) < 0.6
    latency = rng.exponential(3.0, (rounds, n)).astype(np.float32)
    drop = rng.random((rounds, n)) < 0.4
    z, t, aux = gate_windows(zeta, tau, avail, latency, drop)
    overlap = z * aux["unavail"] + z * aux["dropout"] \
        + aux["unavail"] * aux["dropout"]
    assert not overlap.any()
    assert (z + aux["unavail"] + aux["dropout"] <= zeta).all()
    assert (t[z == 1] > 0).all() and (t[z == 0] == 0).all()


@pytest.mark.parametrize("pi,rho", [(0.3, 0.0), (0.7, 0.5), (0.9, 0.8)])
def test_het_availability_stationary_distribution(pi, rho):
    """P(on->on) = rho + (1-rho) pi, P(off->on) = (1-rho) pi gives a chain
    whose stationary availability is exactly pi, for any persistence."""
    m = HeterogeneityModel(num_devices=400, availability=pi,
                           avail_persist=rho, seed=3)
    states = m.sample_states(500)
    assert abs(states.mean() - pi) < 0.02
    # device-resident twin: same stationary law from jax.random draws
    from repro.scenarios.heterogeneity import _jax_draws

    avail_j, _, _ = _jax_draws(m, jax.random.key(4), 500)
    assert abs(float(np.asarray(avail_j).mean()) - pi) < 0.02


def test_het_provider_masks_disjoint_from_successes():
    fl = FLConfig(num_devices=16, rounds=200, mobility_model="exponential",
                  mean_contact=30.0, mean_intercontact=80.0,
                  het_dropout=0.3, het_availability=0.7, het_compute_mean=2.0)
    p = ScenarioProvider.from_config(fl, 200, 0)
    zeta, tau, _ = p.schedule()
    aux = p.aux
    assert aux is not None and set(aux) == {"unavail", "dropout"}
    assert aux["dropout"].sum() > 0 and aux["unavail"].sum() > 0
    assert not (zeta * aux["dropout"]).any()  # a dropped cell never succeeds
    assert not (zeta * aux["unavail"]).any()
    assert ((tau > 0) == (zeta == 1)).all()
    # round accessor slices the same masks
    r = int(np.nonzero(aux["dropout"].sum(1))[0][0])
    np.testing.assert_array_equal(p.aux_round(r)["dropout"], aux["dropout"][r])


def test_het_disabled_is_identity():
    fl = FLConfig(num_devices=8, rounds=50, mobility_model="exponential")
    fl_het = dataclasses.replace(fl, het_dropout=0.0, het_availability=1.0,
                                 het_compute_mean=0.0)
    z0, t0, _ = ScenarioProvider.from_config(fl, 50, 0).schedule()
    p = ScenarioProvider.from_config(fl_het, 50, 0)
    z1, t1, _ = p.schedule()
    assert p.aux is None and p.aux_round(0) is None
    np.testing.assert_array_equal(z0, z1)
    np.testing.assert_array_equal(t0, t1)


def test_het_dropout_never_yields_device_table_success():
    """End-to-end: with dropout=1 every contact is lost before the engine,
    so the flight recorder sees zero successes and only dropout losses."""
    from repro.telemetry import DeviceTable, TelemetrySuite, AFL_REGISTRY

    fl = FLConfig(num_devices=8, rounds=40, mobility_model="exponential",
                  mean_contact=30.0, mean_intercontact=60.0, het_dropout=1.0)
    provider = ScenarioProvider.from_config(fl, 40, 0)
    zeta, tau, _ = provider.schedule()
    assert zeta.sum() == 0  # nothing survives the gate...
    aux = provider.aux
    assert aux["dropout"].sum() > 0  # ...because dropout ate real contacts
    # DeviceTable accounting: update() per round + update_het() on the masks
    table = DeviceTable(8)
    state = table.init_state()
    for r in range(40):
        zr = jnp.asarray(zeta[r], jnp.float32)
        metrics = {"uploads": zr, "success": zr, "theta": jnp.zeros(8),
                   "bits": jnp.zeros(8), "energy": jnp.zeros(8)}
        state = table.update(state, metrics, jnp.asarray(tau[r]))
        state = table.update_het(state, provider.aux_round(r))
    assert float(state["successes"].sum()) == 0.0
    assert float(state["dropouts"].sum()) == float(aux["dropout"].sum())
    assert float(state["unavail"].sum()) == 0.0


def test_het_jax_apply_matches_numpy_in_distribution():
    from repro.scenarios.heterogeneity import jax_apply

    rng = np.random.default_rng(5)
    rounds, n = 400, 64
    zeta = (rng.random((rounds, n)) < 0.5).astype(np.int32)
    tau = np.where(zeta, rng.exponential(10.0, (rounds, n)), 0.0) \
        .astype(np.float32)
    m = HeterogeneityModel(num_devices=n, availability=0.8, avail_persist=0.3,
                           compute_mean=2.0, dropout=0.2, seed=9)
    z_np, t_np, a_np = m.apply(zeta, tau)
    z_j, t_j, a_j = jax_apply(m, jnp.asarray(zeta), jnp.asarray(tau))
    # independent PRNGs: survival and loss rates agree within CI bands
    assert abs(z_np.mean() - float(jnp.mean(z_j.astype(jnp.float32)))) < 0.03
    for k in ("unavail", "dropout"):
        assert abs(a_np[k].mean() - float(jnp.mean(a_j[k]))) < 0.02
    surv_np = t_np[z_np == 1].mean()
    surv_j = float(jnp.sum(t_j) / jnp.maximum(jnp.sum(z_j), 1))
    assert abs(surv_np - surv_j) / surv_np < 0.15


# ---------------------------------------------------------------------------
# provider backends: the jax path through ScenarioProvider
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rwp", "gauss_markov", "manhattan",
                                  "hotspot", "static"])
def test_provider_jax_backend_produces_rounds(name):
    fl = FLConfig(num_devices=16, rounds=100, mobility_model=name,
                  speed=10.0, area=600.0, seed=1, scenario_backend="jax")
    zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
    assert zeta.shape == tau.shape == h2.shape == (100, 16)
    assert isinstance(zeta, jax.Array)  # device-resident, no host copy
    z, t, h = np.asarray(zeta), np.asarray(tau), np.asarray(h2)
    if name != "static":
        assert z.sum() > 0, name
    assert ((t > 0) == (z == 1)).all()
    assert (h > 0).all() and np.isfinite(h).all()


def test_provider_unknown_backend_raises():
    fl = FLConfig(num_devices=4, rounds=10, scenario_backend="tpu9000")
    with pytest.raises(KeyError):
        ScenarioProvider.from_config(fl)


def test_provider_jax_backend_exponential_stays_host_side():
    """The renewal abstraction has no kinematics to port: backend='jax'
    falls through to the (already vectorized) host build."""
    fl = FLConfig(num_devices=8, rounds=30, mobility_model="exponential",
                  scenario_backend="jax")
    zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
    assert isinstance(zeta, np.ndarray)
    assert zeta.shape == (30, 8)


def test_differential_smoke_n512():
    """Tier-1 smoke at N=512: both backends build the same scenario point
    and agree on contact statistics within CI bands; the extraction layer
    agrees exactly on the shared in-range matrix."""
    n, rounds = 512, 60
    base = dict(num_devices=n, rounds=rounds, mobility_model="gauss_markov",
                speed=10.0, area=800.0, seed=4)
    z_np, t_np, _ = ScenarioProvider.from_config(
        FLConfig(**base)).schedule()
    z_j, t_j, _ = ScenarioProvider.from_config(
        FLConfig(scenario_backend="jax", **base)).schedule()
    z_j, t_j = np.asarray(z_j), np.asarray(t_j)
    assert z_j.shape == z_np.shape == (rounds, n)
    assert abs(z_j.mean() - z_np.mean()) / z_np.mean() < 0.2
    assert abs(t_j[z_j == 1].mean() - t_np[z_np == 1].mean()) \
        / t_np[z_np == 1].mean() < 0.2
    # shared-mask differential at the same scale: exact
    m = JaxGaussMarkovModel(num_devices=n, area=800.0, mean_speed=10.0,
                            seed=4)
    mask = np.asarray(m.trace(rounds * 10.0, 1.0).in_range(100.0))
    z_o, t_o = _oracle_rounds(mask, 1.0, rounds, 10.0)
    z_x, t_x = rounds_from_in_range(mask, 1.0, rounds, 10.0)
    np.testing.assert_array_equal(np.asarray(z_x), z_o)
    np.testing.assert_array_equal(np.asarray(t_x), t_o)


@pytest.mark.slow
def test_differential_large_n_1e5():
    """N=1e5: generation + extraction stay device-resident and exact vs
    the oracle on the shared mask (short horizon bounds memory)."""
    n = 100_000
    m = JaxGaussMarkovModel(num_devices=n, area=2000.0, mean_speed=10.0,
                            seed=0)
    zeta, tau, h2 = jax_schedule_from_model(m, rounds=20, round_duration=10.0)
    assert zeta.shape == (20, n)
    z = np.asarray(zeta)
    assert 0 < z.mean() < 1
    mask = np.asarray(m.trace(200.0, 1.0).in_range(100.0))
    z_o, t_o = _oracle_rounds(mask, 1.0, 20, 10.0)
    z_j, t_j = rounds_from_in_range(mask, 1.0, 20, 10.0)
    np.testing.assert_array_equal(np.asarray(z_j), z_o)
    np.testing.assert_array_equal(np.asarray(t_j), t_o)


@pytest.mark.slow
def test_generation_scales_to_1e6_devices():
    """The million-device point: a (short-horizon) trace + schedule builds
    without host round-trips or O(N) Python anywhere."""
    n = 1_000_000
    m = JaxGaussMarkovModel(num_devices=n, area=5000.0, mean_speed=10.0,
                            seed=1)
    zeta, tau, _ = jax_schedule_from_model(m, rounds=4, round_duration=5.0)
    assert zeta.shape == (4, n)
    z, t = np.asarray(zeta), np.asarray(tau)
    assert ((t > 0) == (z == 1)).all()
    assert 0 < z.mean() < 1
