"""Compressor semantics: budget respect, EF round-trips, engine parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import (
    SCALE_BITS,
    CompressorState,
    FixedKbCompressor,
    JointCompressor,
    QSGDCompressor,
    TopKCompressor,
    dither_u01,
    init_state,
    solve_kb,
)
from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core import sparsify as SP
from repro.core.afl import afl_init, afl_round
from repro.core.runner import run_afl
from repro.experiments import DataShard, run_afl_scanned
from repro.launch.train import build_device_data
from repro.models.registry import build_model

RNG = np.random.default_rng(7)


def _tree(scale=1.0):
    return {
        "a": jnp.asarray(RNG.normal(0, scale, (64, 8)), jnp.float32),
        "b": jnp.asarray(RNG.normal(0, 2 * scale, (100,)), jnp.float32),
    }


TREE = _tree()
S = sum(l.size for l in jax.tree.leaves(TREE))
CODECS = [
    TopKCompressor(s=S),
    TopKCompressor(s=S, u=8),
    JointCompressor(s=S),
    JointCompressor(s=S, per_layer=True),
    QSGDCompressor(s=S),
    FixedKbCompressor(s=S, k_frac=0.1, b=8),
]
BUDGETS = [0.0, 33.0, 50.0, 500.0, 5000.0, 50_000.0, 1e7]


def _codec_id(c):
    return (type(c).__name__ + (f"_u{c.u}" if hasattr(c, "u") else "")
            + ("_perlayer" if getattr(c, "per_layer", False) else ""))


@pytest.mark.parametrize("comp", CODECS, ids=_codec_id)
def test_realized_bits_within_budget(comp):
    """Acceptance: realised upload bits never exceed tau*A for ANY budget."""
    state = init_state(TREE, jax.random.key(0))
    for budget in BUDGETS:
        _, _, stats = comp.compress(TREE, jnp.float32(budget), state)
        assert float(stats["bits"]) <= budget + 1e-3, (budget, float(stats["bits"]))
        assert 0.0 <= float(stats["k"]) <= S


@pytest.mark.parametrize("comp", CODECS, ids=_codec_id)
def test_error_feedback_identity(comp):
    """payload + new error == signal + old error (nothing is lost)."""
    state = init_state(TREE, jax.random.key(1))
    state = CompressorState(
        error=jax.tree.map(lambda l: l * 0.25, _tree(0.5)), key=state.key
    )
    payload, state2, _ = comp.compress(TREE, jnp.float32(4000.0), state)
    xt = jax.tree.map(jnp.add, TREE, state.error)
    recon = jax.tree.map(jnp.add, payload, state2.error)
    for a, b in zip(jax.tree.leaves(xt), jax.tree.leaves(recon)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_ef_state_roundtrips_through_scan():
    """CompressorState threads a lax.scan: residuals telescope, so the sum
    of payloads + the final error reconstructs the sum of inputs."""
    comp = JointCompressor(s=S)
    signals = [_tree() for _ in range(6)]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *signals)
    state0 = init_state(signals[0], jax.random.key(3))

    def body(state, x):
        payload, state, stats = comp.compress(x, jnp.float32(3000.0), state)
        return state, (payload, stats["bits"])

    state, (payloads, bits) = jax.lax.scan(body, state0, stacked)
    total_in = jax.tree.map(lambda l: jnp.sum(l, 0), stacked)
    total_out = jax.tree.map(lambda p, e: jnp.sum(p, 0) + e, payloads,
                             state.error)
    for a, b in zip(jax.tree.leaves(total_in), jax.tree.leaves(total_out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    assert float(jnp.max(bits)) <= 3000.0
    # the PRNG key advanced every step (stochastic codec state is live)
    assert not np.array_equal(np.asarray(jax.random.key_data(state.key)),
                              np.asarray(jax.random.key_data(state0.key)))


def test_topk32_matches_sparsify_tree():
    """u=32 top-k codec reproduces the seed operator exactly."""
    comp = TopKCompressor(s=S, u=32)
    state = init_state(TREE, jax.random.key(0))
    budget = 300.0 * (32 + comp.index_bits)  # buys exactly 300 coords
    payload, state2, stats = comp.compress(TREE, jnp.float32(budget), state)
    up, err, k = SP.sparsify_tree(TREE, 300.0, method="exact")
    assert float(stats["k"]) == float(k) == 300.0
    for a, b in zip(jax.tree.leaves(payload), jax.tree.leaves(up)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state2.error), jax.tree.leaves(err)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantization_unbiased():
    """Stochastic rounding: payload averages to the signal across seeds."""
    x = {"v": jnp.asarray(RNG.normal(0, 1, 512), jnp.float32)}
    c = QSGDCompressor(s=512, b_max=4)
    acc = jnp.zeros(512)
    n = 200
    for i in range(n):
        st = init_state(x, jax.random.key(i))
        pay, _, stats = c.compress(x, jnp.float32(512 * 4 + SCALE_BITS), st)
        assert float(stats["b"]) == 4.0
        acc = acc + pay["v"]
    # step = amax/(2^3-1); mean error ~ step/sqrt(n) << step
    step = float(jnp.max(jnp.abs(x["v"]))) / 7.0
    assert float(jnp.max(jnp.abs(acc / n - x["v"]))) < step / 2


def test_joint_solve_kb_budget_scaling():
    """More budget -> never fewer coords, never a lower bit-width regime
    collapse; huge budgets saturate k=s and grow b."""
    grid = tuple(range(2, 17))
    ks, bs = [], []
    for budget in [100.0, 1e3, 1e4, 1e5, 1e6, 1e8]:
        k, b = solve_kb(jnp.float32(budget), S, 10, grid)
        ks.append(float(k))
        bs.append(float(b))
    assert all(a <= b for a, b in zip(ks, ks[1:]))
    assert ks[-1] == S  # saturates: everything ships
    # ...at high precision (score ties at f32 eps above b~13; argmax takes
    # the first, so "high" not necessarily b_max)
    assert bs[-1] >= 12.0
    assert bs[0] <= bs[-1]


def test_exact_mode_ties_undershoot_not_withhold():
    """Magnitude ties (bf16 buckets, duplicated values) at the threshold
    must not overshoot the budget OR stall uploads: the strict-above
    threshold ships the strictly-larger set."""
    s = 8192
    vals = np.concatenate([
        np.arange(2.0, 52.0),          # 50 distinct magnitudes > 1
        np.ones(1000),                 # a massive tied bucket AT the cutoff
        RNG.uniform(0.0, 0.5, s - 1050),
    ])
    tree = {"w": jnp.asarray(RNG.permutation(vals), jnp.float32)}
    comp = TopKCompressor(s=s)
    state = init_state(tree, jax.random.key(2))
    budget = 500.0 * (32 + comp.index_bits)  # cutoff lands inside the bucket
    _, _, stats = comp.compress(tree, jnp.float32(budget), state)
    assert float(stats["bits"]) <= budget
    assert float(stats["k"]) == 50.0  # the distinct head ships; ties defer
    # bf16-bucketed gradients (the LLM federations) also keep shipping
    x16 = jnp.asarray(RNG.normal(0, 1, s), jnp.bfloat16).astype(jnp.float32)
    comp_j = JointCompressor(s=s)
    for budget in (2e4, 2e5):
        _, _, st2 = comp_j.compress({"w": x16}, jnp.float32(budget), state)
        assert 0.0 < float(st2["bits"]) <= budget


def test_sampled_mode_budget_gate():
    """Sampled thresholds can overshoot k_target; the all-or-nothing gate
    in Compressor.spend still guarantees bits <= budget, and a withheld
    upload parks the whole signal in the EF memory."""
    tree = {"w": jnp.asarray(RNG.normal(0, 1, 300_000), jnp.float32)}
    comp = JointCompressor(s=300_000, method="sampled", sample=4096)
    state = init_state(tree, jax.random.key(5))
    shipped = 0
    for budget in (5e4, 2e5, 1e6, 5e6):
        payload, st2, stats = comp.compress(tree, jnp.float32(budget), state)
        assert float(stats["bits"]) <= budget, budget
        if float(stats["k"]) > 0:
            shipped += 1
        else:  # withheld: nothing on the wire, everything in EF
            assert float(sum(jnp.sum(jnp.abs(l))
                             for l in jax.tree.leaves(payload))) == 0.0
            np.testing.assert_array_equal(
                np.asarray(st2.error["w"]), np.asarray(tree["w"]))
    assert shipped >= 1  # the gate is not vacuously withholding everything


def test_dither_deterministic_and_uniform():
    idx = jnp.arange(100_000)
    u = dither_u01(jnp.int32(42), idx)
    u2 = dither_u01(jnp.int32(42), idx)
    np.testing.assert_array_equal(np.asarray(u), np.asarray(u2))
    assert 0.0 <= float(jnp.min(u)) and float(jnp.max(u)) < 1.0
    assert abs(float(jnp.mean(u)) - 0.5) < 5e-3
    u3 = dither_u01(jnp.int32(43), idx)
    assert float(jnp.mean(jnp.abs(u - u3))) > 0.1  # seed actually matters


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=8, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    return cfg, model, fl, dev, ev


def test_round_bits_never_exceed_contact_budget(federation):
    """Inside a real jitted round: per-device realised bits <= tau * A(p)."""
    from repro.core import mads as M

    cfg, model, fl, dev, ev = federation
    policy = BL.ALL["mads-joint"](model.num_params(), fl)
    ctl = policy.controller
    state = afl_init(model, cfg, fl, jax.random.key(0))
    shard = DataShard(dev, fl.batch_size, seed=0)
    batch = shard.traced_batch(shard.seed_key(0), 0)
    n = fl.num_devices
    zeta = jnp.ones((n,), jnp.float32)
    tau = jnp.asarray(RNG.uniform(0.05, 6.0, n), jnp.float32)
    h2 = jnp.asarray(RNG.uniform(1e-12, 1e-8, n), jnp.float32)
    budgets = jnp.full((n,), 60.0)
    state, m = afl_round(state, batch, zeta, tau, h2, budgets,
                         model=model, cfg=cfg, fl=fl, policy=policy)
    cap = tau * M.rate_bps(m["power"], h2, ctl.bandwidth, ctl.noise_w_hz)
    assert np.all(np.asarray(m["bits"]) <= np.asarray(cap) * (1 + 1e-5) + 1e-3)
    assert float(jnp.sum(m["bits"])) > 0  # something actually shipped


@pytest.mark.parametrize("policy", ["mads-joint", "qsgd"])
def test_scan_loop_equivalence_quantizing(federation, policy):
    """Loop and scan engines agree with a quantising compressor (the EF +
    PRNG codec state round-trips identically through lax.scan)."""
    cfg, model, fl, dev, ev = federation
    shard = DataShard(dev, fl.batch_size, seed=0)
    loop = run_afl(model, cfg, fl, policy, shard, ev, rounds=8, eval_every=4)
    scan = run_afl_scanned(model, cfg, fl, policy, shard, ev, rounds=8,
                           eval_every=4)
    assert loop.history["round"] == scan.history["round"]
    for k in loop.history:
        np.testing.assert_allclose(
            np.asarray(loop.history[k]), np.asarray(scan.history[k]),
            rtol=2e-4, atol=1e-5, err_msg=f"{policy}:{k}",
        )
    assert loop.history["bits_mean"][-1] > 0


def test_compressor_policies_share_compile_class(federation):
    """Grid cache-key treatment: same codec params -> equal engine policies
    (one compile), different codec class -> distinct."""
    from repro.experiments.grid import engine_policy

    cfg, model, fl, dev, ev = federation
    s = model.num_params()
    assert engine_policy(BL.ALL["mads-joint"](s, fl)) == engine_policy(
        BL.ALL["mads-joint"](s, fl))
    assert engine_policy(BL.ALL["mads-joint"](s, fl)) != engine_policy(
        BL.ALL["qsgd"](s, fl))
    assert engine_policy(BL.ALL["mads-joint"](s, fl)) != engine_policy(
        BL.ALL["mads"](s, fl))


@pytest.mark.slow
def test_sweep_all_codecs_resumable(federation, tmp_path):
    """Acceptance: one sweep over {mads, mads-joint, qsgd, fixed-kb} with
    resumable results."""
    from repro.experiments import ExperimentGrid, ResultsStore
    from repro.launch.sweep import run_sweep

    cfg, model, fl, dev, ev = federation
    shard = DataShard(dev, fl.batch_size, seed=0)
    grid = ExperimentGrid(
        policies=("mads", "mads-joint", "qsgd", "fixed-kb"),
        speeds=(10.0,), seeds=(0,), rounds=4, eval_every=2, base=fl,
    )
    store = ResultsStore(str(tmp_path))
    table = run_sweep(grid, store, model, cfg, shard, ev)
    assert all(p in table for p in grid.policies)
    assert store.pending(grid.cells()) == []
    # resume: nothing re-runs, the table rebuilds from disk
    table2 = run_sweep(grid, store, model, cfg, shard, ev)
    assert table2 == table
