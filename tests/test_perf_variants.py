"""§Perf beyond-paper variants: numerical correctness on CPU.

The dry-run proves these lower at scale; here we prove they compute the
right thing: int8 KV cache decode matches the bf16 cache within
quantisation tolerance, int8 experts are finite and trainable, and the
sharded-friendly cross-entropy equals the take_along_axis form.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.registry import build_model, demo_batch

RNG = np.random.default_rng(5)


def test_cross_entropy_matches_take_along_axis():
    logits = jnp.asarray(RNG.normal(0, 2, (4, 16, 64)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, 64, (4, 16)), jnp.int32)
    ours = L.cross_entropy(logits, labels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ref = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
    np.testing.assert_allclose(float(ours), float(ref), rtol=1e-6)


def test_quantize_kv_roundtrip_accuracy():
    x = jnp.asarray(RNG.normal(0, 3, (2, 64, 4, 32)), jnp.float32)
    q, scale = L.quantize_kv(x)
    assert q.dtype == jnp.int8
    back = q.astype(jnp.float32) * scale[..., None]
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


@pytest.mark.parametrize("arch", ["llama3.2-3b", "qwen3-moe-30b-a3b"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    prompt, nxt = tokens[:, :-1], tokens[:, -1]
    _, cache = m.prefill(params, cfg, prompt, max_seq=12)
    lg, _ = m.decode_step(params, cfg, cache, nxt, jnp.asarray(11))

    cfg8 = cfg.replace(kv_cache_dtype="int8")
    m8 = build_model(cfg8)
    _, cache8 = m8.prefill(params, cfg8, prompt, max_seq=12)
    assert cache8["k"].dtype == jnp.int8
    lg8, cache8b = m8.decode_step(params, cfg8, cache8, nxt, jnp.asarray(11))
    assert cache8b["k"].dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(lg.astype(jnp.float32) - lg8.astype(jnp.float32))))
    rel /= float(jnp.max(jnp.abs(lg.astype(jnp.float32)))) + 1e-9
    assert rel < 0.1, rel


def test_int8_experts_finite_and_trainable():
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(expert_dtype="int8")
    m = build_model(cfg)
    params = m.init(jax.random.key(1))
    assert params["layers"]["moe"]["wi_gate"].dtype == jnp.int8
    batch = {k: jnp.asarray(v) for k, v in demo_batch(cfg, 2, 16, RNG).items()}
    loss = m.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    # gradients flow to the (float) non-expert params
    g = jax.grad(lambda p: m.loss_fn(p, cfg, batch), allow_int=True)(params)
    gnorm = float(jnp.sum(jnp.abs(g["layers"]["attn"]["wq"].astype(jnp.float32))))
    assert gnorm > 0


def test_dp_client_rules_replicate_params():
    import numpy as np_

    from jax.sharding import Mesh, PartitionSpec as P

    from repro.launch.steps import RULES_TRAIN_DP
    from repro.sharding import rules as R

    devs = np_.tile(np_.array(jax.devices()[:1]), 8).reshape(2, 2, 2)
    mesh = Mesh(devs, ("pod", "data", "model"))
    ps = R.logical_to_pspec(("embed", "heads", "head_dim"), (512, 8, 64),
                            RULES_TRAIN_DP, mesh)
    assert ps == P()
    ps_b = R.logical_to_pspec(("batch", "seq"), (8, 128), RULES_TRAIN_DP, mesh)
    assert ps_b == P(("pod", "data", "model"))
    ps_c = R.logical_to_pspec(("client", "embed"), (4, 64), RULES_TRAIN_DP, mesh)
    assert ps_c == P(("pod", "data"))
