"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward + one train step on CPU, asserting output
shapes and absence of NaNs.  Decode/prefill paths are exercised where the
family defines them, including prefill->decode consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.registry import build_model, demo_batch

RNG = np.random.default_rng(0)


def _model_and_batch(name, batch=2, seq=32):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b = {k: jnp.asarray(v) for k, v in demo_batch(cfg, batch, seq, RNG).items()}
    return cfg, model, params, b


@pytest.mark.parametrize("name", ASSIGNED_ARCHS + ("resnet9-cifar10", "lanegcn-argoverse"))
def test_forward_and_train_step(name):
    cfg, model, params, batch = _model_and_batch(name)
    loss = model.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: loss not finite"

    # one SGD train step
    grads = jax.grad(lambda p: model.loss_fn(p, cfg, batch))(params)
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = model.loss_fn(new, cfg, batch)
    assert np.isfinite(float(loss2))
    for leaf in jax.tree.leaves(new):
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32)))), name


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_logits_shape(name):
    cfg, model, params, batch = _model_and_batch(name)
    if cfg.family == "audio":
        logits, _ = model.forward(params, cfg, batch["tokens"], frames=batch["frames"])
        assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    elif cfg.family == "vlm":
        logits, _ = model.forward(
            params, cfg, batch["tokens"], vision_embeds=batch["vision_embeds"]
        )
        n_img = batch["vision_embeds"].shape[1]
        assert logits.shape == (
            batch["tokens"].shape[0],
            batch["tokens"].shape[1] + n_img,
            cfg.vocab_size,
        )
    else:
        logits, _ = model.forward(params, cfg, batch["tokens"])
        assert logits.shape == (*batch["tokens"].shape, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name


@pytest.mark.parametrize(
    "name", [a for a in ASSIGNED_ARCHS if a not in ("resnet9-cifar10",)]
)
def test_decode_step_runs(name):
    cfg, model, params, batch = _model_and_batch(name)
    if model.decode_step is None:
        pytest.skip("no decode for this family")
    bsz, max_seq = 2, 16
    cache = model.init_cache(cfg, bsz, max_seq)
    token = jnp.asarray([1, 2], jnp.int32)
    logits, cache2 = model.decode_step(params, cfg, cache, token, jnp.asarray(0))
    assert logits.shape == (bsz, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name
    logits3, _ = model.decode_step(params, cfg, cache2, token, jnp.asarray(1))
    assert np.isfinite(np.asarray(logits3, np.float32)).all()


@pytest.mark.parametrize("name", ["llama3.2-3b", "mamba2-2.7b", "whisper-large-v3"])
def test_prefill_decode_consistency(name):
    """decode(prefill(prompt)) logits match teacher-forced forward logits."""
    cfg, model, params, batch = _model_and_batch(name, batch=1, seq=12)
    tokens = batch["tokens"]
    kw = {"frames": batch["frames"]} if cfg.family == "audio" else {}
    full_logits, _ = model.forward(params, cfg, tokens, **kw)

    prompt, nxt = tokens[:, :-1], tokens[:, -1]
    if cfg.family == "audio":
        last, cache = model.prefill(params, cfg, prompt, frames=batch["frames"],
                                    max_seq=tokens.shape[1])
    elif cfg.family == "ssm":
        last, cache = model.prefill(params, cfg, prompt)
    else:
        last, cache = model.prefill(params, cfg, prompt, max_seq=tokens.shape[1])
    if cfg.family in ("dense", "moe", "vlm"):
        last = last  # (B, V) already
    # prefill last-position logits == forward at position -2
    np.testing.assert_allclose(
        np.asarray(last, np.float32).reshape(-1),
        np.asarray(full_logits[:, -2], np.float32).reshape(-1),
        rtol=3e-2, atol=3e-2,
    )
    # one decode step == forward at last position
    step_logits, _ = model.decode_step(
        params, cfg, cache, nxt, jnp.asarray(prompt.shape[1])
    )
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32).reshape(-1),
        np.asarray(full_logits[:, -1], np.float32).reshape(-1),
        rtol=3e-2, atol=3e-2,
    )
