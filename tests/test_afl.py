"""Algorithm-1 engine invariants (simulation mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.afl import afl_init, afl_round
from repro.models.registry import build_model, demo_batch

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(num_devices=4, rounds=20, batch_size=4)
    state = afl_init(model, cfg, fl, jax.random.key(0))
    batch = {
        k: jnp.asarray(np.stack([demo_batch(cfg, 4, 0, RNG)[k] for _ in range(4)]))
        for k in ("images", "labels")
    }
    budgets = jnp.full((4,), 100.0)
    return cfg, model, fl, state, batch, budgets


def _round(setup, zeta, policy_name="mads", state=None, tau_val=8.0):
    cfg, model, fl, st0, batch, budgets = setup
    st = state if state is not None else st0
    pol = BL.ALL[policy_name](model.num_params(), fl)
    zeta = jnp.asarray(zeta)
    tau = jnp.full((4,), tau_val) * zeta
    h2 = jnp.full((4,), 1e-9)
    return afl_round(st, batch, zeta, tau, h2, budgets,
                     model=model, cfg=cfg, fl=fl, policy=pol)


def test_no_contact_keeps_global_model(setup):
    _, model, fl, state, *_ = setup
    new, m = _round(setup, [0, 0, 0, 0])
    for a, b in zip(jax.tree.leaves(new.w), jax.tree.leaves(state.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(jnp.sum(m["uploads"])) == 0


def test_no_contact_still_trains_locally(setup):
    state0 = setup[3]
    new, _ = _round(setup, [0, 0, 0, 0])
    diff = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(new.w_n), jax.tree.leaves(state0.w_n))
    )
    assert diff > 0  # local SGD moved the device models


def test_contact_resets_gradient_and_staleness(setup):
    new1, _ = _round(setup, [0, 0, 0, 0])
    new2, m = _round(setup, [1, 0, 0, 0], state=new1)
    # device 0 uploaded: g reset, kappa = r
    g0 = sum(float(jnp.sum(jnp.abs(l[0].astype(jnp.float32)))) for l in jax.tree.leaves(new2.g_n))
    g1 = sum(float(jnp.sum(jnp.abs(l[1].astype(jnp.float32)))) for l in jax.tree.leaves(new2.g_n))
    assert g0 == 0.0 and g1 > 0.0
    assert int(new2.kappa[0]) == int(new2.rnd)
    assert int(new2.kappa[1]) == 0
    # device 0 synchronised with the new global model
    for wl, wn in zip(jax.tree.leaves(new2.w), jax.tree.leaves(new2.w_n)):
        np.testing.assert_allclose(
            np.asarray(wl, np.float32), np.asarray(wn[0], np.float32), rtol=1e-5
        )


def test_error_feedback_conservation(setup):
    """After upload: e_new = x - S(x), and w moved by exactly S(x)/N."""
    cfg, model, fl, state, batch, budgets = setup
    new1, _ = _round(setup, [1, 1, 1, 1])
    # error memory nonzero (k < s under finite contact window)
    e = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32)))) for l in jax.tree.leaves(new1.e_n))
    assert e > 0


def test_staleness_grows_without_contact(setup):
    st = setup[3]
    for r in range(3):
        st, m = _round(setup, [0, 0, 0, 0], state=st)
    assert float(jnp.max(m["theta"])) == 3.0


def test_energy_monotone_nondecreasing(setup):
    st = setup[3]
    prev = 0.0
    for _ in range(3):
        st, _ = _round(setup, [1, 1, 0, 0], state=st)
        cur = float(jnp.sum(st.energy))
        assert cur >= prev
        prev = cur


def test_sfl_policy_freezes_idle_devices(setup):
    cfg, model, fl, state, batch, budgets = setup
    pol = BL.sfl_spar(model.num_params(), fl)
    zeta = jnp.asarray([0, 0, 0, 0])
    new, _ = afl_round(state, batch, zeta, jnp.zeros(4), jnp.full((4,), 1e-9),
                       budgets, model=model, cfg=cfg, fl=fl, policy=pol)
    for a, b in zip(jax.tree.leaves(new.w_n), jax.tree.leaves(state.w_n)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_full_upload_policy_all_or_nothing(setup):
    cfg, model, fl, state, batch, budgets = setup
    pol = BL.fedasync(model.num_params(), fl)
    # tau tiny -> full model cannot fit -> upload fails, w unchanged
    zeta = jnp.asarray([1, 1, 1, 1])
    new, m = afl_round(state, batch, zeta, jnp.full((4,), 1e-4),
                       jnp.full((4,), 1e-9), budgets,
                       model=model, cfg=cfg, fl=fl, policy=pol)
    assert float(jnp.sum(m["k"])) == 0.0
    for a, b in zip(jax.tree.leaves(new.w), jax.tree.leaves(state.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_upload_conserves_mass_in_memory(setup):
    """u=8 wire format: x - upload == e_after (EF absorbs quantisation)."""
    import dataclasses as _dc

    cfg, model, fl, state, batch, budgets = setup
    fl8 = _dc.replace(fl, value_bits=8)
    pol = BL.mads(model.num_params(), fl8)
    zeta = jnp.asarray([1, 1, 1, 1])
    new, m = afl_round(state, batch, zeta, jnp.full((4,), 8.0),
                       jnp.full((4,), 1e-9), budgets,
                       model=model, cfg=cfg, fl=fl8, policy=pol)
    # reconstruct x for device 0: e was 0, g = eta*grad; upload+e_after == x
    x0 = jax.tree.map(lambda g: g[0], new.e_n)  # e_after for dev 0
    assert np.isfinite(
        sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32)))) for l in jax.tree.leaves(x0))
    )
    # and the uploaded values changed the global model
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(new.w), jax.tree.leaves(state.w))
    )
    assert delta > 0
