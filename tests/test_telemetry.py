"""Telemetry subsystem: registry algebra, engine parity, exporters.

The load-bearing contract is bit-identity: histogram bin counts are sums
of 0/1 weights (exact integers in f32, reduction-order independent), so
the loop runner, the scan engine, and the pjit distributed step must emit
*bit-identical* histograms for the same seeded run — pinned here with
``assert_array_equal``, not allclose.  The slow test re-checks the vmapped
seed axis sharded over a mesh of 2 simulated host devices.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.distributed import DistConfig, init_state, make_afl_train_step, run_afl_rounds
from repro.core.runner import build_provider, resolve_telemetry, run_afl, sample_budgets
from repro.experiments import DataShard, run_afl_scanned, run_seed_batch
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import (
    AFL_REGISTRY,
    HIST_KEYS,
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MetricRegistry,
    PhaseTracer,
    export_bench,
    load_bench,
    merge_fetched,
    parse_csv_row,
    read_jsonl,
    to_jsonable,
)

ROUNDS, EVERY = 8, 4
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=ROUNDS, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    return cfg, model, fl, shard, ev


def _assert_snapshots_equal(a: dict, b: dict, err=""):
    """Hists + integral counters exactly equal; float totals to 1e-6."""
    for k in a["hist"]:
        np.testing.assert_array_equal(a["hist"][k], b["hist"][k],
                                      err_msg=f"{err} hist {k!r}")
    for k in ("rounds", "contacts", "successes"):
        assert a["counters"][k] == b["counters"][k], (err, k)
    for k in ("bits_total", "energy_total"):
        np.testing.assert_allclose(a["counters"][k], b["counters"][k],
                                   rtol=1e-6, err_msg=f"{err} {k}")
    assert a["gauges"] == b["gauges"], err


# ---------------------------------------------------------------------------
# registry algebra (host-only, fast)
# ---------------------------------------------------------------------------


def test_hist_keys_single_source():
    """core.runner re-exports the telemetry module's HIST_KEYS object."""
    from repro.core.runner import HIST_KEYS as runner_keys
    from repro.experiments.scan_engine import HIST_KEYS as scan_keys

    assert runner_keys is HIST_KEYS
    assert scan_keys is HIST_KEYS


def test_engines_emit_same_history_keys(federation):
    cfg, model, fl, shard, ev = federation
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=2, eval_every=2)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=2,
                           eval_every=2)
    assert set(loop.history) == set(HIST_KEYS)
    assert set(scan.history) == set(HIST_KEYS)


def test_histogram_bins_underflow_interior_overflow():
    reg = MetricRegistry(
        counters=(Counter("n"),), gauges=(Gauge("r"),),
        histograms=(Histogram("h", edges=(1.0, 2.0, 4.0)),),
    )
    s = reg.init_state()
    # 0.5 -> underflow; 1.0, 1.5 -> [1,2); 3.0 -> [2,4); 4.0, 9.0 -> overflow
    vals = jnp.asarray([0.5, 1.0, 1.5, 3.0, 4.0, 9.0])
    s = reg.update(s, counters={"n": 6.0}, gauges={"r": 1.0},
                   hists={"h": (vals, jnp.ones_like(vals))})
    np.testing.assert_array_equal(np.asarray(s["hist"]["h"]),
                                  [1.0, 2.0, 1.0, 2.0])
    assert float(s["counters"]["n"]) == 6.0
    assert float(s["gauges"]["r"]) == 1.0
    # masked weights drop samples without perturbing the others
    s = reg.update(s, hists={"h": (vals, jnp.asarray([0., 1., 0., 1., 0., 1.]))})
    np.testing.assert_array_equal(np.asarray(s["hist"]["h"]),
                                  [1.0, 3.0, 2.0, 3.0])
    with pytest.raises(KeyError):
        reg.update(s, hists={"nope": (vals, vals)})


def test_merge_associative_and_stacked():
    reg = AFL_REGISTRY
    rng = np.random.default_rng(0)
    states = []
    for i in range(3):
        s = reg.init_state()
        m = {
            "uploads": jnp.asarray(rng.integers(0, 2, 4), jnp.float32),
            "success": jnp.asarray(rng.integers(0, 2, 4), jnp.float32),
            "theta": jnp.asarray(rng.uniform(1, 100, 4), jnp.float32),
            "bits": jnp.asarray(rng.uniform(1e3, 1e8, 4), jnp.float32),
            "k": jnp.asarray(rng.uniform(1, 1e6, 4), jnp.float32),
            "b": jnp.asarray(rng.uniform(1, 32, 4), jnp.float32),
            "energy": jnp.asarray(rng.uniform(0, 1, 4), jnp.float32),
        }
        from repro.telemetry import record_round

        states.append(record_round(reg, s, m, jnp.asarray([1., 3., 9., 80.])))
    a, b, c = states
    left = reg.fetch(reg.merge(reg.merge(a, b), c))
    right = reg.fetch(reg.merge(a, reg.merge(b, c)))
    _assert_snapshots_equal(left, right, "associativity")
    # merge_stacked == the pairwise fold
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), a, b, c)
    _assert_snapshots_equal(reg.fetch(reg.merge_stacked(stacked)), left,
                            "stacked")
    # numpy mirror of merge agrees with the device merge
    _assert_snapshots_equal(
        merge_fetched([reg.fetch(a), reg.fetch(b), reg.fetch(c)]), left,
        "merge_fetched")


# ---------------------------------------------------------------------------
# engine parity: loop vs scan vs pjit step, bit-identical histograms
# ---------------------------------------------------------------------------


def test_loop_scan_parity_bit_identical(federation):
    """Same seeded mads run through both engines: identical snapshots."""
    cfg, model, fl, shard, ev = federation
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=3, telemetry=AFL_REGISTRY)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                           eval_every=EVERY, seed=3, telemetry=AFL_REGISTRY)
    assert loop.telemetry is not None and scan.telemetry is not None
    _assert_snapshots_equal(loop.telemetry, scan.telemetry, "loop-vs-scan")
    assert loop.telemetry["counters"]["rounds"] == ROUNDS
    # something was actually observed
    assert loop.telemetry["counters"]["contacts"] > 0
    assert sum(loop.telemetry["hist"]["staleness"]) == \
        loop.telemetry["counters"]["contacts"]


def test_dist_step_telemetry_matches_loop(federation):
    """The pjit step's in-program record_round equals the loop engine's."""
    cfg, model, fl, shard, ev = federation
    policy = BL.ALL["mads"](model.num_params(), fl)
    dcfg = DistConfig(
        num_clients=fl.num_devices, learning_rate=fl.learning_rate,
        rounds=fl.rounds, state_dtype="float32", upload_dtype="float32",
    )
    step = jax.jit(make_afl_train_step(model, cfg, dcfg, policy.controller,
                                       telemetry=AFL_REGISTRY))
    provider = build_provider(fl, "mads", None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    key = shard.seed_key(0)
    flat = lambda b: jax.tree.map(
        lambda v: v.reshape((-1,) + v.shape[2:]), b)
    _, hist, tstate = run_afl_rounds(
        step, init_state(model, dcfg, jax.random.key(0)), provider,
        lambda r: flat(shard.traced_batch(key, r)), budgets,
        rounds=ROUNDS, telemetry=AFL_REGISTRY,
    )
    assert len(hist) == ROUNDS
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=0, telemetry=AFL_REGISTRY)
    _assert_snapshots_equal(AFL_REGISTRY.fetch(tstate), loop.telemetry,
                            "dist-vs-loop")


def test_seed_vmap_telemetry_matches_independent(federation):
    """Vmapped seeds carry per-seed states; each slice equals the
    independent scanned run, and merging recovers the totals."""
    cfg, model, fl, shard, ev = federation
    batch = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                           rounds=ROUNDS, eval_every=EVERY,
                           telemetry=AFL_REGISTRY)
    snaps = [r.telemetry for r in batch]
    assert all(s is not None for s in snaps)
    for seed, snap in zip((0, 1), snaps):
        ind = run_afl_scanned(model, cfg, fl, "mads", shard, ev,
                              rounds=ROUNDS, eval_every=EVERY, seed=seed,
                              telemetry=AFL_REGISTRY)
        _assert_snapshots_equal(snap, ind.telemetry, f"vmap seed {seed}")
    merged = merge_fetched(snaps)
    assert merged["counters"]["rounds"] == 2 * ROUNDS
    np.testing.assert_array_equal(
        merged["hist"]["staleness"],
        np.asarray(snaps[0]["hist"]["staleness"], np.float64)
        + np.asarray(snaps[1]["hist"]["staleness"], np.float64))


def test_fl_config_knob_and_resolution(federation):
    """fl.telemetry=True turns on the built-in registry; off -> None."""
    import dataclasses

    cfg, model, fl, shard, ev = federation
    assert resolve_telemetry(fl, None) is None
    assert resolve_telemetry(fl, AFL_REGISTRY) is AFL_REGISTRY
    fl_on = dataclasses.replace(fl, telemetry=True)
    assert resolve_telemetry(fl_on, None) is AFL_REGISTRY
    res = run_afl_scanned(model, cfg, fl_on, "mads", shard, ev,
                          rounds=ROUNDS, eval_every=EVERY, seed=3)
    assert res.telemetry is not None
    off = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=2,
                          eval_every=2)
    assert off.telemetry is None


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_and_fence():
    tracer = PhaseTracer()
    with tracer.span("compile"):
        pass
    for _ in range(3):
        with tracer.span("execute", r=1):
            tracer.fence(jnp.ones(4) * 2)
            tracer.fence({"host": [1, 2]})  # non-array pytree: no-op
    tot = tracer.totals()
    assert tot["compile"]["count"] == 1
    assert tot["execute"]["count"] == 3
    assert tot["execute"]["total_s"] >= tot["execute"]["max_s"] > 0
    assert "execute" in tracer.summary()
    events = tracer.events()
    assert len(events) == 4 and all(e["kind"] == "span" for e in events)
    json.dumps(events)  # sink-ready
    # without profile_dir, start/stop are no-ops
    tracer.start()
    tracer.stop()


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_jsonl_sink_roundtrip_and_aggregate(tmp_path):
    """write -> read -> aggregate: the sweep telemetry file contract."""
    reg = AFL_REGISTRY
    s = reg.init_state()
    from repro.telemetry import record_round

    m = {"uploads": jnp.asarray([1., 1., 0., 0.]),
         "success": jnp.asarray([1., 0., 0., 0.]),
         "theta": jnp.asarray([2., 5., 1., 1.]),
         "bits": jnp.asarray([1e5, 0., 0., 0.]),
         "k": jnp.asarray([100., 0., 0., 0.]),
         "b": jnp.asarray([8., 0., 0., 0.]),
         "energy": jnp.asarray([0.5, 0.2, 0., 0.])}
    s = record_round(reg, s, m, jnp.asarray([3., 7., 0., 0.]))
    snap = reg.fetch(s)

    path = tmp_path / "telemetry.jsonl"
    with JsonlSink(str(path)) as sink:
        sink.emit({"kind": "metrics", "group": "a", **to_jsonable(snap)})
        sink.emit({"kind": "metrics", "group": "b", **to_jsonable(snap)})
        sink.emit({"kind": "span", "name": "run", "duration_s": 1.0})
        with pytest.raises(TypeError):
            sink.emit({"bad": object()})  # eager validation
    loaded = read_jsonl(str(path))
    assert len(loaded) == 3
    metrics = [r for r in loaded if r["kind"] == "metrics"]
    agg = merge_fetched(metrics)
    assert agg["counters"]["rounds"] == 2.0
    assert agg["counters"]["contacts"] == 4.0
    np.testing.assert_array_equal(
        np.asarray(agg["hist"]["staleness"]),
        2.0 * np.asarray(snap["hist"]["staleness"], np.float64))
    # summary renders from a merged JSONL snapshot too
    assert "success_rate" in reg.summary(agg)


def test_bench_export_trajectory_and_compare(tmp_path):
    rows = ["afl_scan_n8,6235.5,rounds_per_s=160.4;speedup_vs_loop=2.4x",
            "afl_loop_n8,15111.4,rounds_per_s=66.2"]
    rec = parse_csv_row(rows[0])
    assert rec["name"] == "afl_scan_n8"
    assert rec["metrics"] == {"rounds_per_s": 160.4, "speedup_vs_loop": 2.4}

    out = tmp_path / "bench"
    p = export_bench("afl", rows, out_dir=str(out), meta={"smoke": True})
    assert os.path.basename(p) == "BENCH_afl.json"
    data = load_bench(p)
    assert data["suite"] == "afl" and data["history"] == []
    assert data["rows"][1]["metrics"]["rounds_per_s"] == 66.2
    # re-export pushes the previous rows onto the trajectory
    export_bench("afl", rows, out_dir=str(out))
    assert len(load_bench(p)["history"]) == 1

    # regression checker: ok at parity, exit 1 on a >30% throughput drop
    base = tmp_path / "base"
    export_bench("afl", rows, out_dir=str(base))
    script = os.path.join(os.path.dirname(__file__), "..", "tools",
                          "bench_compare.py")
    ok = subprocess.run(
        [sys.executable, script, str(base / "BENCH_afl.json"), p, "--check"],
        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    slow = ["afl_scan_n8,6235.5,rounds_per_s=100.0;speedup_vs_loop=1.5x",
            "afl_loop_n8,15111.4,rounds_per_s=66.2"]
    export_bench("afl", slow, out_dir=str(out))
    bad = subprocess.run(
        [sys.executable, script, str(base / "BENCH_afl.json"), p, "--check"],
        capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSED" in bad.stdout
    # missing baseline: fresh branches pass
    none = subprocess.run(
        [sys.executable, script, str(base / "nope.json"), p, "--check"],
        capture_output=True, text=True)
    assert none.returncode == 0


# ---------------------------------------------------------------------------
# 2 simulated host devices: sharded seed axis, same histograms
# ---------------------------------------------------------------------------


MESH_SCRIPT = r"""
import jax
from repro.launch.mesh import force_host_device_count
force_host_device_count(2)
import numpy as np

from repro.configs import FLConfig, get_config
from repro.experiments import DataShard, run_seed_batch
from repro.launch.mesh import make_seed_mesh
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import AFL_REGISTRY, merge_fetched

assert jax.device_count() == 2, jax.devices()

cfg = get_config("resnet9-cifar10").replace(d_model=4)
model = build_model(cfg)
fl = FLConfig(num_devices=4, rounds=6, batch_size=8, learning_rate=0.02,
              mean_contact=6.0, mean_intercontact=30.0,
              energy_budget=(40.0, 80.0))
dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
shard = DataShard(dev, fl.batch_size, seed=0)

mesh = make_seed_mesh(2)
assert mesh is not None
sharded = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                         rounds=6, eval_every=3, mesh=mesh,
                         telemetry=AFL_REGISTRY)
single = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                        rounds=6, eval_every=3, mesh=None,
                        telemetry=AFL_REGISTRY)
for i in range(2):
    a, b = sharded[i].telemetry, single[i].telemetry
    for k in a["hist"]:
        assert np.array_equal(a["hist"][k], b["hist"][k]), (i, k)
    for k in ("rounds", "contacts", "successes"):
        assert a["counters"][k] == b["counters"][k], (i, k)
m = merge_fetched([r.telemetry for r in sharded])
assert m["counters"]["rounds"] == 12
print("MESH_TELEMETRY_OK")
"""


@pytest.mark.slow
def test_two_device_mesh_histograms_bit_identical():
    """Seed axis sharded over 2 simulated host devices: per-seed telemetry
    histograms equal the unsharded run's exactly (integer-count contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_TELEMETRY_OK" in out.stdout
