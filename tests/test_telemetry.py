"""Telemetry subsystem: registry algebra, engine parity, exporters.

The load-bearing contract is bit-identity: histogram bin counts are sums
of 0/1 weights (exact integers in f32, reduction-order independent), so
the loop runner, the scan engine, and the pjit distributed step must emit
*bit-identical* histograms for the same seeded run — pinned here with
``assert_array_equal``, not allclose.  The slow test re-checks the vmapped
seed axis sharded over a mesh of 2 simulated host devices.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.distributed import DistConfig, init_state, make_afl_train_step, run_afl_rounds
from repro.core.runner import build_provider, resolve_telemetry, run_afl, sample_budgets
from repro.experiments import DataShard, run_afl_scanned, run_seed_batch
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import (
    AFL_REGISTRY,
    HIST_KEYS,
    Counter,
    DeviceTable,
    Gauge,
    Histogram,
    JsonlSink,
    MetricRegistry,
    PhaseTracer,
    TelemetrySuite,
    TheoryProbes,
    export_bench,
    load_bench,
    merge_fetched,
    parse_csv_row,
    participation_gini,
    read_jsonl,
    render_report,
    report_from_config,
    to_jsonable,
    top_stragglers,
)

ROUNDS, EVERY = 8, 4
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def federation():
    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=4, rounds=ROUNDS, batch_size=8, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0, energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    return cfg, model, fl, shard, ev


def _assert_snapshots_equal(a: dict, b: dict, err=""):
    """Hists + integral counters exactly equal; float totals to 1e-6."""
    for k in a["hist"]:
        np.testing.assert_array_equal(a["hist"][k], b["hist"][k],
                                      err_msg=f"{err} hist {k!r}")
    for k in ("rounds", "contacts", "successes"):
        assert a["counters"][k] == b["counters"][k], (err, k)
    for k in ("bits_total", "energy_total"):
        np.testing.assert_allclose(a["counters"][k], b["counters"][k],
                                   rtol=1e-6, err_msg=f"{err} {k}")
    assert a["gauges"] == b["gauges"], err


# count-like (N,) fields: exact-integer f32 updates, bit-identical across
# engines; float accumulators agree to rounding; e_norm2 is a param-dim
# reduction whose summation order differs between compiled programs, so it
# only gets an absolute tolerance (values near denormal scale here)
_TABLE_EXACT = ("rounds", "contacts", "successes", "failures",
                "last_contact", "staleness_sum", "staleness_max")
_TABLE_CLOSE = ("tau_sum", "bits_sum", "energy_sum")


def _assert_tables_equal(a: dict, b: dict, err=""):
    for k in _TABLE_EXACT:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{err} table {k}")
    for k in _TABLE_CLOSE:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-6,
                                   err_msg=f"{err} table {k}")
    np.testing.assert_allclose(a["e_norm2"], b["e_norm2"], rtol=0.5,
                               atol=1e-9, err_msg=f"{err} table e_norm2")


def _assert_probes_equal(a: dict, b: dict, err=""):
    for k in ("rounds", "contacts", "successes"):
        assert a[k] == b[k], (err, k)
    for k in a:
        np.testing.assert_allclose(a[k], b[k], rtol=1e-5, atol=1e-9,
                                   err_msg=f"{err} probe {k}")


def _assert_suites_equal(a: dict, b: dict, err=""):
    _assert_snapshots_equal(a["metrics"], b["metrics"], err)
    _assert_tables_equal(a["device"], b["device"], err)
    _assert_probes_equal(a["probes"], b["probes"], err)


def _suite_for(model, fl):
    return TelemetrySuite(
        metrics=AFL_REGISTRY, device=DeviceTable(fl.num_devices),
        probes=TheoryProbes(s=model.num_params(), u=fl.value_bits),
    )


# ---------------------------------------------------------------------------
# registry algebra (host-only, fast)
# ---------------------------------------------------------------------------


def test_hist_keys_single_source():
    """core.runner re-exports the telemetry module's HIST_KEYS object."""
    from repro.core.runner import HIST_KEYS as runner_keys
    from repro.experiments.scan_engine import HIST_KEYS as scan_keys

    assert runner_keys is HIST_KEYS
    assert scan_keys is HIST_KEYS


def test_engines_emit_same_history_keys(federation):
    cfg, model, fl, shard, ev = federation
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=2, eval_every=2)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=2,
                           eval_every=2)
    assert set(loop.history) == set(HIST_KEYS)
    assert set(scan.history) == set(HIST_KEYS)


def test_histogram_bins_underflow_interior_overflow():
    reg = MetricRegistry(
        counters=(Counter("n"),), gauges=(Gauge("r"),),
        histograms=(Histogram("h", edges=(1.0, 2.0, 4.0)),),
    )
    s = reg.init_state()
    # 0.5 -> underflow; 1.0, 1.5 -> [1,2); 3.0 -> [2,4); 4.0, 9.0 -> overflow
    vals = jnp.asarray([0.5, 1.0, 1.5, 3.0, 4.0, 9.0])
    s = reg.update(s, counters={"n": 6.0}, gauges={"r": 1.0},
                   hists={"h": (vals, jnp.ones_like(vals))})
    np.testing.assert_array_equal(np.asarray(s["hist"]["h"]),
                                  [1.0, 2.0, 1.0, 2.0])
    assert float(s["counters"]["n"]) == 6.0
    assert float(s["gauges"]["r"]) == 1.0
    # masked weights drop samples without perturbing the others
    s = reg.update(s, hists={"h": (vals, jnp.asarray([0., 1., 0., 1., 0., 1.]))})
    np.testing.assert_array_equal(np.asarray(s["hist"]["h"]),
                                  [1.0, 3.0, 2.0, 3.0])
    with pytest.raises(KeyError):
        reg.update(s, hists={"nope": (vals, vals)})


def test_merge_associative_and_stacked():
    reg = AFL_REGISTRY
    rng = np.random.default_rng(0)
    states = []
    for i in range(3):
        s = reg.init_state()
        m = {
            "uploads": jnp.asarray(rng.integers(0, 2, 4), jnp.float32),
            "success": jnp.asarray(rng.integers(0, 2, 4), jnp.float32),
            "theta": jnp.asarray(rng.uniform(1, 100, 4), jnp.float32),
            "bits": jnp.asarray(rng.uniform(1e3, 1e8, 4), jnp.float32),
            "k": jnp.asarray(rng.uniform(1, 1e6, 4), jnp.float32),
            "b": jnp.asarray(rng.uniform(1, 32, 4), jnp.float32),
            "energy": jnp.asarray(rng.uniform(0, 1, 4), jnp.float32),
        }
        from repro.telemetry import record_round

        states.append(record_round(reg, s, m, jnp.asarray([1., 3., 9., 80.])))
    a, b, c = states
    left = reg.fetch(reg.merge(reg.merge(a, b), c))
    right = reg.fetch(reg.merge(a, reg.merge(b, c)))
    _assert_snapshots_equal(left, right, "associativity")
    # merge_stacked == the pairwise fold
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), a, b, c)
    _assert_snapshots_equal(reg.fetch(reg.merge_stacked(stacked)), left,
                            "stacked")
    # numpy mirror of merge agrees with the device merge
    _assert_snapshots_equal(
        merge_fetched([reg.fetch(a), reg.fetch(b), reg.fetch(c)]), left,
        "merge_fetched")


# ---------------------------------------------------------------------------
# engine parity: loop vs scan vs pjit step, bit-identical histograms
# ---------------------------------------------------------------------------


def test_loop_scan_parity_bit_identical(federation):
    """Same seeded mads run through both engines: identical snapshots."""
    cfg, model, fl, shard, ev = federation
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=3, telemetry=AFL_REGISTRY)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                           eval_every=EVERY, seed=3, telemetry=AFL_REGISTRY)
    assert loop.telemetry is not None and scan.telemetry is not None
    _assert_snapshots_equal(loop.telemetry, scan.telemetry, "loop-vs-scan")
    assert loop.telemetry["counters"]["rounds"] == ROUNDS
    # something was actually observed
    assert loop.telemetry["counters"]["contacts"] > 0
    assert sum(loop.telemetry["hist"]["staleness"]) == \
        loop.telemetry["counters"]["contacts"]


def test_dist_step_telemetry_matches_loop(federation):
    """The pjit step's in-program record_round equals the loop engine's."""
    cfg, model, fl, shard, ev = federation
    policy = BL.ALL["mads"](model.num_params(), fl)
    dcfg = DistConfig(
        num_clients=fl.num_devices, learning_rate=fl.learning_rate,
        rounds=fl.rounds, state_dtype="float32", upload_dtype="float32",
    )
    step = jax.jit(make_afl_train_step(model, cfg, dcfg, policy.controller,
                                       telemetry=AFL_REGISTRY))
    provider = build_provider(fl, "mads", None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    key = shard.seed_key(0)
    flat = lambda b: jax.tree.map(
        lambda v: v.reshape((-1,) + v.shape[2:]), b)
    _, hist, tstate = run_afl_rounds(
        step, init_state(model, dcfg, jax.random.key(0)), provider,
        lambda r: flat(shard.traced_batch(key, r)), budgets,
        rounds=ROUNDS, telemetry=AFL_REGISTRY,
    )
    assert len(hist) == ROUNDS
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=0, telemetry=AFL_REGISTRY)
    _assert_snapshots_equal(AFL_REGISTRY.fetch(tstate), loop.telemetry,
                            "dist-vs-loop")


def test_seed_vmap_telemetry_matches_independent(federation):
    """Vmapped seeds carry per-seed states; each slice equals the
    independent scanned run, and merging recovers the totals."""
    cfg, model, fl, shard, ev = federation
    batch = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                           rounds=ROUNDS, eval_every=EVERY,
                           telemetry=AFL_REGISTRY)
    snaps = [r.telemetry for r in batch]
    assert all(s is not None for s in snaps)
    for seed, snap in zip((0, 1), snaps):
        ind = run_afl_scanned(model, cfg, fl, "mads", shard, ev,
                              rounds=ROUNDS, eval_every=EVERY, seed=seed,
                              telemetry=AFL_REGISTRY)
        _assert_snapshots_equal(snap, ind.telemetry, f"vmap seed {seed}")
    merged = merge_fetched(snaps)
    assert merged["counters"]["rounds"] == 2 * ROUNDS
    np.testing.assert_array_equal(
        merged["hist"]["staleness"],
        np.asarray(snaps[0]["hist"]["staleness"], np.float64)
        + np.asarray(snaps[1]["hist"]["staleness"], np.float64))


def test_fl_config_knob_and_resolution(federation):
    """fl.telemetry=True turns on the built-in registry; off -> None."""
    import dataclasses

    cfg, model, fl, shard, ev = federation
    assert resolve_telemetry(fl, None) is None
    assert resolve_telemetry(fl, AFL_REGISTRY) is AFL_REGISTRY
    fl_on = dataclasses.replace(fl, telemetry=True)
    assert resolve_telemetry(fl_on, None) is AFL_REGISTRY
    res = run_afl_scanned(model, cfg, fl_on, "mads", shard, ev,
                          rounds=ROUNDS, eval_every=EVERY, seed=3)
    assert res.telemetry is not None
    off = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=2,
                          eval_every=2)
    assert off.telemetry is None


# ---------------------------------------------------------------------------
# TelemetrySuite: flight recorder + probes through every engine
# ---------------------------------------------------------------------------


def test_resolve_telemetry_suite_knobs(federation):
    """FLConfig suite knobs build an equivalent (hashable) suite each call
    — one jit-cache key — and probes require a model size."""
    import dataclasses

    cfg, model, fl, shard, ev = federation
    s = model.num_params()
    fl_suite = dataclasses.replace(fl, telemetry_perdevice=True,
                                   telemetry_probes=True)
    t1 = resolve_telemetry(fl_suite, None, s=s)
    t2 = resolve_telemetry(fl_suite, None, s=s)
    assert isinstance(t1, TelemetrySuite)
    assert t1 == t2 and hash(t1) == hash(t2)
    assert t1.device.n == fl.num_devices
    assert t1.probes.s == s
    # s=0 (unknown model size): probes silently drop, table stays
    t3 = resolve_telemetry(fl_suite, None, s=0)
    assert t3.device is not None and t3.probes is None
    # an explicit registry still wins over the knobs
    assert resolve_telemetry(fl_suite, AFL_REGISTRY, s=s) is AFL_REGISTRY
    # device-only knob: no probes section in the snapshot
    fl_dev = dataclasses.replace(fl, telemetry_perdevice=True)
    t4 = resolve_telemetry(fl_dev, None, s=s)
    snap = t4.fetch(t4.init_state())
    assert snap["device"] is not None and snap.get("probes") is None


def test_suite_loop_scan_parity(federation):
    """Same seeded run, suite carried through both engines: per-device
    count fields bit-identical, probe accumulators equal."""
    cfg, model, fl, shard, ev = federation
    suite = _suite_for(model, fl)
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=3, telemetry=suite)
    scan = run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                           eval_every=EVERY, seed=3, telemetry=suite)
    _assert_suites_equal(loop.telemetry, scan.telemetry, "suite loop-vs-scan")
    dev = loop.telemetry["device"]
    assert dev["rounds"] == ROUNDS
    # table totals reconcile with the registry's federation-wide counters
    c = loop.telemetry["metrics"]["counters"]
    assert float(dev["contacts"].sum()) == c["contacts"]
    assert float(dev["successes"].sum()) == c["successes"]
    np.testing.assert_allclose(float(dev["bits_sum"].sum()),
                               c["bits_total"], rtol=1e-5)
    # and with the probe accumulators
    p = loop.telemetry["probes"]
    assert p["rounds"] == ROUNDS
    assert p["contacts"] == c["contacts"]
    assert p["successes"] == c["successes"]


def test_suite_dist_step_matches_loop(federation):
    """The pjit step's in-program suite recording equals the loop's."""
    cfg, model, fl, shard, ev = federation
    from repro.core.distributed import telemetry_shardings

    suite = _suite_for(model, fl)
    policy = BL.ALL["mads"](model.num_params(), fl)
    dcfg = DistConfig(
        num_clients=fl.num_devices, learning_rate=fl.learning_rate,
        rounds=fl.rounds, state_dtype="float32", upload_dtype="float32",
    )
    step = jax.jit(make_afl_train_step(model, cfg, dcfg, policy.controller,
                                       telemetry=suite))
    provider = build_provider(fl, "mads", None, ROUNDS, 0)
    budgets = sample_budgets(fl, 0)
    key = shard.seed_key(0)
    flat = lambda b: jax.tree.map(
        lambda v: v.reshape((-1,) + v.shape[2:]), b)
    _, hist, tstate = run_afl_rounds(
        step, init_state(model, dcfg, jax.random.key(0)), provider,
        lambda r: flat(shard.traced_batch(key, r)), budgets,
        rounds=ROUNDS, telemetry=suite,
    )
    loop = run_afl(model, cfg, fl, "mads", shard, ev, rounds=ROUNDS,
                   eval_every=EVERY, seed=0, telemetry=suite)
    _assert_suites_equal(suite.fetch(tstate), loop.telemetry,
                         "suite dist-vs-loop")
    # sharding spec: (N,) table rows on the client axis, all else replicated
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    sh = telemetry_shardings(suite, mesh)
    assert set(sh) == {"metrics", "device", "probes"}
    assert all(s.spec == jax.sharding.PartitionSpec("data")
               for f, s in sh["device"].items() if f != "rounds")
    assert sh["device"]["rounds"].spec == jax.sharding.PartitionSpec()


def test_suite_seed_vmap_slices(federation):
    """Vmapped seeds: each per-seed suite slice equals the independent
    scanned run; merging recovers federation totals per FIELD_KIND."""
    cfg, model, fl, shard, ev = federation
    suite = _suite_for(model, fl)
    batch = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                           rounds=ROUNDS, eval_every=EVERY, telemetry=suite)
    snaps = [r.telemetry for r in batch]
    assert all(s is not None for s in snaps)
    for seed, snap in zip((0, 1), snaps):
        ind = run_afl_scanned(model, cfg, fl, "mads", shard, ev,
                              rounds=ROUNDS, eval_every=EVERY, seed=seed,
                              telemetry=suite)
        _assert_suites_equal(snap, ind.telemetry, f"suite vmap seed {seed}")
    merged = merge_fetched(snaps)
    dev = merged["device"]
    assert dev["rounds"] == 2 * ROUNDS  # sum across seeds
    np.testing.assert_array_equal(
        dev["contacts"],
        np.asarray(snaps[0]["device"]["contacts"])
        + np.asarray(snaps[1]["device"]["contacts"]))
    np.testing.assert_array_equal(  # max-kind field merges as max
        dev["staleness_max"],
        np.maximum(snaps[0]["device"]["staleness_max"],
                   snaps[1]["device"]["staleness_max"]))
    assert merged["probes"]["rounds"] == 2 * ROUNDS
    # the merged snapshot survives the JSONL sink round-trip
    rec = json.loads(json.dumps(to_jsonable(merged)))
    assert len(rec["device"]["contacts"]) == fl.num_devices
    assert rec["probes"]["contacts"] == merged["probes"]["contacts"]


def test_straggler_extraction_and_gini():
    """Host-side row extraction orders starved devices first."""
    table = DeviceTable(4)
    snap = {
        "contacts": np.asarray([9., 0., 4., 2.]),
        "successes": np.asarray([8., 0., 2., 1.]),
        "failures": np.asarray([1., 0., 2., 1.]),
        "last_contact": np.asarray([10., 0., 6., 9.]),
        "staleness_sum": np.asarray([9., 0., 40., 4.]),
        "staleness_max": np.asarray([2., 0., 30., 3.]),
        "tau_sum": np.asarray([18., 0., 8., 4.]),
        "bits_sum": np.asarray([9e6, 0., 4e6, 2e6]),
        "energy_sum": np.asarray([90., 0., 40., 20.]),
        "e_norm2": np.asarray([1e-3, 0., 2e-3, 5e-4]),
        "rounds": 10.0,
    }
    worst = top_stragglers(snap, k=2)
    assert [r["device"] for r in worst] == [1, 3]
    assert worst[0]["contacts"] == 0.0 and worst[0]["success_rate"] == 0.0
    assert worst[1]["staleness_mean"] == pytest.approx(2.0)
    gini = participation_gini(snap)
    assert 0.0 < gini < 1.0
    uniform = dict(snap, contacts=np.full(4, 5.0))
    assert participation_gini(uniform) == pytest.approx(0.0, abs=1e-9)
    # summary renders without touching devices
    assert "stale_mean" in table.summary(snap)


def test_probes_calibrated_synthetic():
    """Drive the probes with a synthetic run matching the theory's
    generative model (tau ~ Exp(c), Proposition-1 spend): measured terms
    land on the closed forms."""
    from repro.core import theory

    s, u, c, lam, delta, rate = 4096, 16, 6.0, 30.0, 10.0, 50.0
    n_dev, n_rounds = 64, 400
    probes = TheoryProbes(s=s, u=u)
    state = probes.init_state()
    rng = np.random.default_rng(7)
    since = np.zeros(n_dev)  # rounds since last successful upload
    bitcost = u + np.log2(s)
    # Lemma 2 counts staleness in rounds of length delta; a round overlaps
    # a contact with probability 1 - exp(-delta/lam) under the renewal model
    p_contact = 1.0 - np.exp(-delta / lam)
    for _ in range(n_rounds):
        okf = (rng.random(n_dev) < p_contact).astype(np.float32)
        tau = rng.exponential(c, n_dev).astype(np.float32) * okf
        k = np.minimum(tau * rate / bitcost, s)
        succ = okf * (k >= 1.0)
        theta = since  # staleness in rounds at this round
        m = {"uploads": jnp.asarray(okf), "success": jnp.asarray(succ),
             "theta": jnp.asarray(theta, jnp.float32),
             "k": jnp.asarray(k, jnp.float32),
             "bits": jnp.asarray(tau * rate * (k >= 1.0), jnp.float32),
             "energy": jnp.zeros(n_dev, jnp.float32),
             "x_norm2": jnp.ones(n_dev, jnp.float32)}
        state = probes.update(state, m, jnp.asarray(tau))
        since = np.where(succ > 0, 0.0, since + 1.0)
    rep = probes.report(probes.fetch(state), c=c, lam=lam, delta=delta,
                        rate=rate, n=n_dev)
    t = rep["terms"]
    # P(k >= 1) = P(tau >= bitcost/rate) = gamma exactly under Exp(c)
    assert abs(t["success_rate"]["delta"]) < 0.03
    assert t["success_rate"]["expected"] == pytest.approx(
        theory.gamma(rate, c, s, u))
    # E[(s-k)/s] matches the Monte-Carlo closed form
    assert abs(t["error_fraction"]["delta"]) < 0.05
    # Lemma 2 is a bound for a different renewal model — same order of
    # magnitude is the meaningful check
    th = t["staleness_second_moment"]
    assert th["expected"] == pytest.approx(
        theory.staleness_second_moment(c, lam, delta))
    assert 0.3 < (th["measured"] + 1.0) / th["expected"] < 3.0
    # measured mean rate self-calibrates to the true A (bits = rate * tau)
    assert rep["measured"]["mean_rate"] == pytest.approx(rate, rel=1e-4)
    th1 = rep["theorem1"]
    assert th1["total"] > 0 and np.isfinite(th1["total"])
    assert th1["total"] == pytest.approx(
        th1["t1_init_gap"] + th1["t2_sparsify_staleness_coupling"]
        + th1["t3_staleness_sq"] + th1["t4_grad_noise"])
    # the terminal table renders every term
    assert "success_rate" in probes.summary(rep)


def test_probe_report_from_config(federation):
    """End-to-end: a scanned run with probes produces a finite report at
    the FLConfig's contact operating point."""
    import dataclasses

    cfg, model, fl, shard, ev = federation
    fl_p = dataclasses.replace(fl, telemetry_probes=True)
    res = run_afl_scanned(model, cfg, fl_p, "mads", shard, ev, rounds=ROUNDS,
                          eval_every=EVERY, seed=3)
    assert res.telemetry is not None and res.telemetry["probes"] is not None
    suite = resolve_telemetry(fl_p, None, s=model.num_params())
    rep = report_from_config(suite.probes, res.telemetry["probes"], fl_p)
    assert rep["c"] == fl.mean_contact and rep["lam"] == fl.mean_intercontact
    assert set(rep["terms"]) == {"error_fraction",
                                 "staleness_second_moment", "success_rate"}
    for t in rep["terms"].values():
        assert np.isfinite(t["measured"]) and np.isfinite(t["expected"])
    assert 0.0 <= rep["terms"]["success_rate"]["measured"] <= 1.0
    assert np.isfinite(rep["theorem1"]["total"])


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_spans_and_fence():
    tracer = PhaseTracer()
    with tracer.span("compile"):
        pass
    for _ in range(3):
        with tracer.span("execute", r=1):
            tracer.fence(jnp.ones(4) * 2)
            tracer.fence({"host": [1, 2]})  # non-array pytree: no-op
    tot = tracer.totals()
    assert tot["compile"]["count"] == 1
    assert tot["execute"]["count"] == 3
    assert tot["execute"]["total_s"] >= tot["execute"]["max_s"] > 0
    assert "execute" in tracer.summary()
    events = tracer.events()
    assert len(events) == 4 and all(e["kind"] == "span" for e in events)
    json.dumps(events)  # sink-ready
    # without profile_dir, start/stop are no-ops
    tracer.start()
    tracer.stop()


def test_tracer_nested_spans_and_exceptions():
    """Nested spans record parent/depth; a raising span still lands its
    record (with the error type) and the stack unwinds cleanly."""
    tracer = PhaseTracer()
    with tracer.span("outer"):
        with tracer.span("inner"):
            pass
        with pytest.raises(ValueError):
            with tracer.span("broken"):
                raise ValueError("boom")
    with tracer.span("after"):  # stack recovered: top-level again
        pass
    ev = {e["name"]: e for e in tracer.events()}
    assert ev["inner"]["parent"] == "outer" and ev["inner"]["depth"] == 1
    assert ev["broken"]["parent"] == "outer"
    assert ev["broken"]["error"] == "ValueError"
    assert "error" not in ev["inner"]
    assert "parent" not in ev["outer"] and "parent" not in ev["after"]
    assert ev["outer"]["duration_s"] >= ev["inner"]["duration_s"]
    json.dumps(list(ev.values()))  # sink-ready with the new fields


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_jsonl_sink_roundtrip_and_aggregate(tmp_path):
    """write -> read -> aggregate: the sweep telemetry file contract."""
    reg = AFL_REGISTRY
    s = reg.init_state()
    from repro.telemetry import record_round

    m = {"uploads": jnp.asarray([1., 1., 0., 0.]),
         "success": jnp.asarray([1., 0., 0., 0.]),
         "theta": jnp.asarray([2., 5., 1., 1.]),
         "bits": jnp.asarray([1e5, 0., 0., 0.]),
         "k": jnp.asarray([100., 0., 0., 0.]),
         "b": jnp.asarray([8., 0., 0., 0.]),
         "energy": jnp.asarray([0.5, 0.2, 0., 0.])}
    s = record_round(reg, s, m, jnp.asarray([3., 7., 0., 0.]))
    snap = reg.fetch(s)

    path = tmp_path / "telemetry.jsonl"
    with JsonlSink(str(path)) as sink:
        sink.emit({"kind": "metrics", "group": "a", **to_jsonable(snap)})
        sink.emit({"kind": "metrics", "group": "b", **to_jsonable(snap)})
        sink.emit({"kind": "span", "name": "run", "duration_s": 1.0})
        with pytest.raises(TypeError):
            sink.emit({"bad": object()})  # eager validation
    loaded = read_jsonl(str(path))
    assert len(loaded) == 3
    metrics = [r for r in loaded if r["kind"] == "metrics"]
    agg = merge_fetched(metrics)
    assert agg["counters"]["rounds"] == 2.0
    assert agg["counters"]["contacts"] == 4.0
    np.testing.assert_array_equal(
        np.asarray(agg["hist"]["staleness"]),
        2.0 * np.asarray(snap["hist"]["staleness"], np.float64))
    # summary renders from a merged JSONL snapshot too
    assert "success_rate" in reg.summary(agg)


def test_jsonl_sink_sanitizes_nonfinite(tmp_path, caplog):
    """NaN/inf become null (valid JSON) with a warning; serialisability is
    still validated eagerly."""
    import logging

    path = tmp_path / "t.jsonl"
    with caplog.at_level(logging.WARNING, logger="repro.telemetry.export"):
        with JsonlSink(str(path)) as sink:
            sink.emit({"kind": "metrics", "ok": 1.5, "bad": float("nan"),
                       "worse": [float("inf"), 2.0],
                       "nested": {"neg": float("-inf")}})
    assert "sanitized 3 non-finite" in caplog.text
    rec = read_jsonl(str(path))[0]  # strict json.loads round-trips
    assert rec["ok"] == 1.5 and rec["bad"] is None
    assert rec["worse"] == [None, 2.0] and rec["nested"]["neg"] is None


def test_render_report_sections(tmp_path):
    """Events from a suite run render every report section; the CLI
    wrapper writes the same document."""
    table = DeviceTable(2)
    probes = TheoryProbes(s=1024, u=8)
    ts = table.init_state()
    ps = probes.init_state()
    reg = AFL_REGISTRY.init_state()
    from repro.telemetry import record_round

    m = {"uploads": jnp.asarray([1., 0.]), "success": jnp.asarray([1., 0.]),
         "theta": jnp.asarray([2., 5.]), "bits": jnp.asarray([1e5, 0.]),
         "k": jnp.asarray([100., 0.]), "b": jnp.asarray([8., 0.]),
         "energy": jnp.asarray([0.5, 0.]),
         "x_norm2": jnp.asarray([1., 1.]),
         "e_norm2": jnp.asarray([1e-4, 2e-4])}
    tau = jnp.asarray([3., 0.])
    reg = record_round(AFL_REGISTRY, reg, m, tau)
    ts = table.update(ts, m, tau)
    ps = probes.update(ps, m, tau)
    snap = {"metrics": AFL_REGISTRY.fetch(reg), "device": table.fetch(ts),
            "probes": probes.fetch(ps)}
    rep = probes.report(snap["probes"], c=6.0, lam=30.0, delta=10.0)
    events = [
        {"kind": "span", "name": "group", "duration_s": 2.0},
        {"kind": "span", "name": "compile", "parent": "group", "depth": 1,
         "duration_s": 1.5},
        {"kind": "span", "name": "broken", "parent": "group", "depth": 1,
         "duration_s": 0.1, "error": "ValueError"},
        {"kind": "group_metrics", "group": "mads/exp/v10", "seeds": 1,
         **to_jsonable(snap)},
        {"kind": "metrics", **to_jsonable(snap)},
        {"kind": "probe_report", "group": "mads/exp/v10", **rep},
    ]
    json.dumps(events)
    bench = {"suite": "afl", "rows": [parse_csv_row(
        "afl_scan_n8,6235.5,rounds_per_s=160.4")], "history": []}
    text = render_report(events, bench=bench, title="T")
    for section in ("# T", "## Phase breakdown", "## Federation counters",
                    "## Distributions", "## Per-group results",
                    "## Stragglers", "Participation Gini",
                    "## Theory vs measured", "Theorem-1",
                    "## Bench trajectory", "(1 raised)", "mads/exp/v10",
                    "afl_scan_n8"):
        assert section in text, section
    # plain-registry events (no suite sections) still render
    plain = render_report([{"kind": "metrics",
                            **to_jsonable(snap["metrics"])}])
    assert "## Federation counters" in plain
    assert "## Stragglers" not in plain
    # CLI wrapper: same renderer end to end
    tpath = tmp_path / "telemetry.jsonl"
    with JsonlSink(str(tpath)) as sink:
        sink.extend(events)
    script = os.path.join(os.path.dirname(__file__), "..", "tools",
                          "report.py")
    out = subprocess.run(
        [sys.executable, script, str(tpath), "--title", "T"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    rendered = open(tmp_path / "report.md").read()
    assert "## Theory vs measured" in rendered


def test_bench_export_trajectory_and_compare(tmp_path):
    rows = ["afl_scan_n8,6235.5,rounds_per_s=160.4;speedup_vs_loop=2.4x",
            "afl_loop_n8,15111.4,rounds_per_s=66.2"]
    rec = parse_csv_row(rows[0])
    assert rec["name"] == "afl_scan_n8"
    assert rec["metrics"] == {"rounds_per_s": 160.4, "speedup_vs_loop": 2.4}

    out = tmp_path / "bench"
    p = export_bench("afl", rows, out_dir=str(out), meta={"smoke": True})
    assert os.path.basename(p) == "BENCH_afl.json"
    data = load_bench(p)
    assert data["suite"] == "afl" and data["history"] == []
    assert data["rows"][1]["metrics"]["rounds_per_s"] == 66.2
    # re-export pushes the previous rows onto the trajectory
    export_bench("afl", rows, out_dir=str(out))
    assert len(load_bench(p)["history"]) == 1

    # regression checker: ok at parity, exit 1 on a >30% throughput drop
    base = tmp_path / "base"
    export_bench("afl", rows, out_dir=str(base))
    script = os.path.join(os.path.dirname(__file__), "..", "tools",
                          "bench_compare.py")
    ok = subprocess.run(
        [sys.executable, script, str(base / "BENCH_afl.json"), p, "--check"],
        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    slow = ["afl_scan_n8,6235.5,rounds_per_s=100.0;speedup_vs_loop=1.5x",
            "afl_loop_n8,15111.4,rounds_per_s=66.2"]
    export_bench("afl", slow, out_dir=str(out))
    bad = subprocess.run(
        [sys.executable, script, str(base / "BENCH_afl.json"), p, "--check"],
        capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSED" in bad.stdout
    # missing baseline: fresh branches pass
    none = subprocess.run(
        [sys.executable, script, str(base / "nope.json"), p, "--check"],
        capture_output=True, text=True)
    assert none.returncode == 0


# ---------------------------------------------------------------------------
# 2 simulated host devices: sharded seed axis, same histograms
# ---------------------------------------------------------------------------


MESH_SCRIPT = r"""
import jax
from repro.launch.mesh import force_host_device_count
force_host_device_count(2)
import numpy as np

from repro.configs import FLConfig, get_config
from repro.experiments import DataShard, run_seed_batch
from repro.launch.mesh import make_seed_mesh
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import AFL_REGISTRY, merge_fetched

assert jax.device_count() == 2, jax.devices()

cfg = get_config("resnet9-cifar10").replace(d_model=4)
model = build_model(cfg)
fl = FLConfig(num_devices=4, rounds=6, batch_size=8, learning_rate=0.02,
              mean_contact=6.0, mean_intercontact=30.0,
              energy_budget=(40.0, 80.0))
dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
shard = DataShard(dev, fl.batch_size, seed=0)

mesh = make_seed_mesh(2)
assert mesh is not None
sharded = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                         rounds=6, eval_every=3, mesh=mesh,
                         telemetry=AFL_REGISTRY)
single = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                        rounds=6, eval_every=3, mesh=None,
                        telemetry=AFL_REGISTRY)
for i in range(2):
    a, b = sharded[i].telemetry, single[i].telemetry
    for k in a["hist"]:
        assert np.array_equal(a["hist"][k], b["hist"][k]), (i, k)
    for k in ("rounds", "contacts", "successes"):
        assert a["counters"][k] == b["counters"][k], (i, k)
m = merge_fetched([r.telemetry for r in sharded])
assert m["counters"]["rounds"] == 12
print("MESH_TELEMETRY_OK")
"""


@pytest.mark.slow
def test_two_device_mesh_histograms_bit_identical():
    """Seed axis sharded over 2 simulated host devices: per-seed telemetry
    histograms equal the unsharded run's exactly (integer-count contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_TELEMETRY_OK" in out.stdout


MESH_SUITE_SCRIPT = r"""
import jax
from repro.launch.mesh import force_host_device_count
force_host_device_count(2)
import numpy as np

from repro.configs import FLConfig, get_config
from repro.experiments import DataShard, run_seed_batch
from repro.launch.mesh import make_seed_mesh
from repro.launch.train import build_device_data
from repro.models.registry import build_model
from repro.telemetry import (AFL_REGISTRY, DeviceTable, TelemetrySuite,
                             TheoryProbes, merge_fetched)

assert jax.device_count() == 2, jax.devices()

cfg = get_config("resnet9-cifar10").replace(d_model=4)
model = build_model(cfg)
fl = FLConfig(num_devices=4, rounds=6, batch_size=8, learning_rate=0.02,
              mean_contact=6.0, mean_intercontact=30.0,
              energy_budget=(40.0, 80.0))
dev, ev = build_device_data(cfg, fl, train_n=160, eval_n=64, seed=0)
shard = DataShard(dev, fl.batch_size, seed=0)
suite = TelemetrySuite(
    metrics=AFL_REGISTRY, device=DeviceTable(fl.num_devices),
    probes=TheoryProbes(s=model.num_params(), u=fl.value_bits))

mesh = make_seed_mesh(2)
assert mesh is not None
sharded = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                         rounds=6, eval_every=3, mesh=mesh, telemetry=suite)
single = run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=[0, 1],
                        rounds=6, eval_every=3, mesh=None, telemetry=suite)
EXACT = ("rounds", "contacts", "successes", "failures", "last_contact",
         "staleness_sum", "staleness_max")
for i in range(2):
    a, b = sharded[i].telemetry, single[i].telemetry
    for k in a["metrics"]["hist"]:
        assert np.array_equal(a["metrics"]["hist"][k],
                              b["metrics"]["hist"][k]), (i, k)
    for k in EXACT:
        assert np.array_equal(a["device"][k], b["device"][k]), (i, k)
    for k in ("tau_sum", "bits_sum", "energy_sum"):
        assert np.allclose(a["device"][k], b["device"][k], rtol=1e-6), (i, k)
    for k in ("rounds", "contacts", "successes"):
        assert a["probes"][k] == b["probes"][k], (i, k)
    for k in a["probes"]:
        assert np.allclose(a["probes"][k], b["probes"][k], rtol=1e-5,
                           atol=1e-9), (i, k)
m = merge_fetched([r.telemetry for r in sharded])
assert m["device"]["rounds"] == 12
assert m["probes"]["rounds"] == 12
print("MESH_SUITE_OK")
"""


@pytest.mark.slow
def test_two_device_mesh_suite_bit_identical():
    """The full suite (registry + flight recorder + probes) sharded over 2
    simulated host devices matches the unsharded per-seed snapshots."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", MESH_SUITE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_SUITE_OK" in out.stdout
