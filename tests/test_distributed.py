"""Distributed AFL train step: sharding, lowering, and numerical agreement
with the simulation engine (8 host devices via a subprocess-safe env var is
not used here — these tests run on the single-device default backend with a
1x1 mesh for numerics and rely on tests/test_dryrun_small.py for multi-device
lowering)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import FLConfig, get_config
from repro.core import baselines as BL
from repro.core.afl import afl_init, afl_round
from repro.core.distributed import (
    DistConfig,
    init_state,
    make_afl_train_step,
)
from repro.core.mads import MadsController
from repro.models.registry import build_model, demo_batch

RNG = np.random.default_rng(3)


@pytest.fixture(scope="module")
def dist_setup():
    cfg = get_config("internlm2-1.8b").reduced().replace(num_layers=1)
    model = build_model(cfg)
    dcfg = DistConfig(num_clients=4, learning_rate=0.01, rounds=50,
                      state_dtype="float32", upload_dtype="float32")
    ctl = MadsController(s=model.num_params())
    step = make_afl_train_step(model, cfg, dcfg, ctl)
    state = init_state(model, dcfg, jax.random.key(0))
    return cfg, model, dcfg, ctl, step, state


def test_no_contact_local_training_only(dist_setup):
    cfg, model, dcfg, ctl, step, state = dist_setup
    batch = {k: jnp.asarray(v) for k, v in demo_batch(cfg, 8, 16, RNG).items()}
    z = jnp.zeros(4)
    o = jnp.ones(4)
    new, m = step(state, batch, z, z, o * 1e-9, o * 100.0)
    # global model unchanged, client models moved
    for a, b in zip(jax.tree.leaves(new.w), jax.tree.leaves(state.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(new.w_n), jax.tree.leaves(state.w_n))
    )
    assert moved > 0
    assert float(jnp.sum(m["uploads"])) == 0


def test_contact_updates_global_and_resets(dist_setup):
    cfg, model, dcfg, ctl, step, state = dist_setup
    batch = {k: jnp.asarray(v) for k, v in demo_batch(cfg, 8, 16, RNG).items()}
    o = jnp.ones(4)
    new, m = step(state, batch, o, o * 8.0, o * 1e-9, o * 100.0)
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(new.w), jax.tree.leaves(state.w))
    )
    assert delta > 0
    assert float(jnp.sum(m["uploads"])) == 4
    assert int(new.kappa.min()) == 1
    # contacted clients hold the new global model
    for wl, wn in zip(jax.tree.leaves(new.w), jax.tree.leaves(new.w_n)):
        for i in range(4):
            np.testing.assert_allclose(
                np.asarray(wl, np.float32), np.asarray(wn[i], np.float32),
                rtol=2e-2, atol=2e-2,
            )


def test_matches_simulation_engine_without_contact(dist_setup):
    """Distributed and simulation engines perform identical local SGD."""
    cfg, model, dcfg, ctl, step, state = dist_setup
    fl = FLConfig(num_devices=4, rounds=50, learning_rate=0.01)
    sim = afl_init(model, cfg, fl, jax.random.key(0))
    # share the same initial global model and batches
    sim = sim._replace(w=state.w, w_n=jax.tree.map(lambda l: l.astype(jnp.float32), sim.w_n))
    n, bsz, seq = 4, 2, 16
    flat = demo_batch(cfg, n * bsz, seq, np.random.default_rng(5))
    batch = {k: jnp.asarray(v) for k, v in flat.items()}
    stacked = {k: jnp.asarray(v.reshape(n, bsz, *v.shape[1:])) for k, v in flat.items()}
    z = jnp.zeros(4)
    o = jnp.ones(4)
    new_d, _ = step(state, batch, z, z, o * 1e-9, o * 100.0)
    pol = BL.mads(model.num_params(), fl)
    new_s, _ = afl_round(sim, stacked, z, z * 0.0, o * 1e-9, o * 100.0,
                         model=model, cfg=cfg, fl=fl, policy=pol)
    for a, b in zip(jax.tree.leaves(new_d.w_n), jax.tree.leaves(new_s.w_n)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-4, atol=1e-5
        )


def test_upload_bits_accounted(dist_setup):
    cfg, model, dcfg, ctl, step, state = dist_setup
    batch = {k: jnp.asarray(v) for k, v in demo_batch(cfg, 8, 16, RNG).items()}
    o = jnp.ones(4)
    _, m = step(state, batch, o, o * 4.0, o * 1e-9, o * 100.0)
    assert float(jnp.sum(m["upload_bits"])) > 0
    assert float(jnp.max(m["k"])) <= model.num_params()
