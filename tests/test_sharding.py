"""Logical axis rules: divisibility fallback + pspec construction."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules as R


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh over whatever devices exist: use 1 device x N via reshape
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    from jax.sharding import Mesh

    return Mesh(devs, ("data", "model"))


def _mesh(shape, axes):
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devs = np.tile(np.array(jax.devices()[:1]), n).reshape(shape)
    return Mesh(devs, axes)


def test_heads_divisible_sharded():
    m = _mesh((2, 4), ("data", "model"))
    ps = R.logical_to_pspec(("embed", "heads", "head_dim"), (512, 8, 64),
                            R.RULES_TRAIN, m)
    assert ps == P(None, "model")


def test_heads_indivisible_falls_back_to_head_dim():
    """qwen2-7b case: 28 heads % 16 != 0 -> shard head_dim instead."""
    m = _mesh((1, 16), ("data", "model"))
    ps = R.logical_to_pspec(("embed", "kv_heads", "head_dim"), (3584, 4, 128),
                            R.RULES_TRAIN, m)
    assert ps == P(None, None, "model")


def test_experts_indivisible_unsharded():
    """qwen2-moe: 60 experts % 16 != 0 -> expert_mlp takes model."""
    m = _mesh((1, 16), ("data", "model"))
    ps = R.logical_to_pspec(("experts", "embed", "expert_mlp"), (60, 2048, 1408),
                            R.RULES_TRAIN, m)
    assert ps == P(None, None, "model")


def test_axis_used_once_per_tensor():
    m = _mesh((2, 4), ("data", "model"))
    ps = R.logical_to_pspec(("mlp", "embed", "heads"), (64, 64, 64),
                            R.RULES_TRAIN, m)
    used = [a for a in ps if a is not None]
    assert len(used) == len(set(used))


def test_batch_priority_pod_data():
    m = _mesh((2, 2, 2), ("pod", "data", "model"))
    ps = R.logical_to_pspec(("batch", "seq"), (64, 128), R.RULES_SERVE, m)
    assert ps == P(("pod", "data"))


def test_long_context_cache_seq_sharded_when_batch_one():
    """long_500k: batch=1 unshardable -> cache 'seq' takes the data axis."""
    m = _mesh((4, 2), ("data", "model"))
    ps = R.logical_to_pspec(
        ("layers", "batch", "seq", "kv_heads", "head_dim"),
        (28, 1, 8192, 8, 128), R.RULES_SERVE, m,
    )
    assert ps == P(None, None, "data", "model")  # kv=8 divisible by model=2


def test_client_axis_on_data():
    m = _mesh((4, 2), ("data", "model"))
    rules = dict(R.RULES_TRAIN, client=[("pod", "data"), ("data",)])
    ps = R.logical_to_pspec(("client", "embed", "mlp"), (4, 64, 64), rules, m)
    assert ps == P("data", None, "model")


def test_param_spec_tree_roundtrip():
    from repro.configs import get_config
    from repro.models.registry import build_model

    model = build_model(get_config("llama3.2-3b").reduced())
    axes = model.param_axes()
    shapes = R.shapes_tree(model.specs)
    flat_axes = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple)
    )
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_axes) == len(flat_shapes)
    for d, s in zip(flat_axes, flat_shapes):
        assert len(d) == len(s.shape), (d, s.shape)
