"""Scenario engine: kinematics, contact extraction, position-coupled
channels, and the ScenarioProvider streaming API."""
import dataclasses

import numpy as np
import pytest

from repro.channel import WirelessChannel
from repro.configs import FLConfig
from repro.mobility.contact import ContactProcess, intervals_to_rounds
from repro.mobility.waypoint import measure_contact_stats
from repro.scenarios import (
    GaussMarkovModel,
    HotspotClusterModel,
    ManhattanGridModel,
    RandomWaypointModel,
    ScenarioProvider,
    Trace,
    contact_intervals,
    gains_along_trace,
)

ALL_MODELS = [
    (RandomWaypointModel, dict(pause_max=0.0)),
    (GaussMarkovModel, {}),
    (ManhattanGridModel, {}),
    (HotspotClusterModel, dict(hotspot_radius=250.0)),
]


# ---------------------------------------------------------------------------
# kinematics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls,extra", ALL_MODELS, ids=lambda x: getattr(x, "__name__", ""))
def test_trace_shapes_and_bounds(cls, extra):
    m = cls(num_devices=6, area=500.0, mean_speed=8.0, seed=3, **extra)
    tr = m.trace(200.0, 1.0)
    assert tr.pos.shape == (200, 6, 2)
    assert tr.mes.shape == (200, 2)
    assert np.isfinite(tr.pos).all()
    assert tr.pos.min() >= -1e-6 and tr.pos.max() <= 500.0 + 1e-6
    assert tr.in_range(100.0).dtype == bool


@pytest.mark.parametrize("cls,extra", ALL_MODELS, ids=lambda x: getattr(x, "__name__", ""))
def test_inverse_speed_law(cls, extra):
    """Paper Fig. 4 / Corollary 1: c ~ C/v and lambda ~ L/v for EVERY
    kinematic model — quadrupling the speed quarters both means."""
    stats = []
    for v, seed in ((3.0, 7), (12.0, 8)):
        m = cls(num_devices=48, area=600.0, mean_speed=v, seed=seed, **extra)
        c, g = measure_contact_stats(m.trace(6000.0, 0.5).in_range(100.0), 0.5)
        stats.append((c, g))
    (c_slow, g_slow), (c_fast, g_fast) = stats
    assert c_fast > 0 and np.isfinite(g_fast)
    # speeds differ 4x; allow +-45% statistical tolerance on the ratio
    assert 2.2 < c_slow / c_fast < 7.3, (c_slow, c_fast)
    assert 2.2 < g_slow / g_fast < 7.3, (g_slow, g_fast)


def test_manhattan_stays_on_streets():
    m = ManhattanGridModel(num_devices=8, area=600.0, mean_speed=10.0,
                           block=100.0, seed=5)
    tr = m.trace(500.0, 1.0)
    # at any instant one coordinate is on a grid line (multiple of block)
    frac = np.abs(tr.pos / 100.0 - np.round(tr.pos / 100.0))
    assert (frac.min(axis=-1) < 1e-6).all()


def test_hotspot_static_at_zero_speed():
    m = HotspotClusterModel(num_devices=5, mean_speed=0.0, seed=2)
    tr = m.trace(50.0, 1.0)
    assert np.all(tr.pos == tr.pos[0])


def test_rwp_mobile_mes_port_matches_seed_statistics():
    """Vectorized RWP reproduces the seed per-step loop's contact stats."""
    from repro.mobility.waypoint import RandomWaypoint

    seed_trace = RandomWaypoint(num_devices=24, mean_speed=10.0, seed=4).simulate(4000.0)
    vec = RandomWaypointModel(num_devices=24, mean_speed=10.0, seed=9,
                              mobile_mes=True)
    vec_in = vec.trace(4000.0, 1.0).in_range(100.0)
    c0, g0 = measure_contact_stats(seed_trace)
    c1, g1 = measure_contact_stats(vec_in)
    assert abs(c1 - c0) / c0 < 0.5
    assert abs(g1 - g0) / g0 < 0.5


# ---------------------------------------------------------------------------
# contact extraction + round mapping
# ---------------------------------------------------------------------------


def test_contact_intervals_simple():
    in_range = np.array([[0, 1], [1, 1], [1, 0], [0, 0], [1, 0]], bool)
    dev, start, dur = contact_intervals(in_range, dt=2.0)
    np.testing.assert_array_equal(dev, [0, 0, 1])
    np.testing.assert_array_equal(start, [2.0, 8.0, 0.0])
    np.testing.assert_array_equal(dur, [4.0, 2.0, 4.0])


def test_intervals_to_rounds_first_writer_wins():
    # two contacts touch round 0; a long contact spans rounds 2..5
    dev = np.array([0, 0, 0])
    start = np.array([2.0, 7.0, 25.0])
    dur = np.array([3.0, 1.0, 30.0])
    zeta, tau = intervals_to_rounds(dev, start, dur, 1, 6, 10.0)
    np.testing.assert_array_equal(zeta.ravel(), [1, 0, 1, 1, 1, 1])
    np.testing.assert_allclose(tau.ravel(), [3.0, 0.0, 30.0, 25.0, 15.0, 5.0])


def test_vectorized_contact_process_matches_loop():
    """Batched renewal sampling reproduces the seed loop's distributions."""
    proc = ContactProcess(64, 4.0, 400.0, 10.0, seed=5)
    zv, tv = proc.sample_rounds(2000)
    zl, tl = proc.sample_rounds_loop(2000)
    assert zv.shape == zl.shape == (2000, 64)
    # tau > 0 exactly on contact rounds
    assert ((tv > 0) == (zv == 1)).all()
    assert abs(zv.mean() - zl.mean()) / zl.mean() < 0.1
    assert abs(tv[zv == 1].mean() - tl[zl == 1].mean()) / tl[zl == 1].mean() < 0.1


# ---------------------------------------------------------------------------
# position-coupled channel
# ---------------------------------------------------------------------------


def test_gains_static_devices_see_constant_channel():
    chan = WirelessChannel(seed=1)
    pos = np.broadcast_to(np.array([[30.0, 0.0], [80.0, 0.0]]), (50, 2, 2)).copy()
    mes = np.zeros((50, 2))
    h2 = gains_along_trace(chan, pos, mes, rng=np.random.default_rng(3))
    # zero displacement -> shadowing and LOS state frozen -> constant gain
    np.testing.assert_allclose(h2, np.broadcast_to(h2[0], h2.shape), rtol=1e-12)


def test_gains_decrease_with_distance_pathloss():
    chan = WirelessChannel(shadow_los_db=0.0, shadow_nlos_db=0.0, seed=1)
    pos = np.broadcast_to(np.array([[15.0, 0.0], [90.0, 0.0]]), (5, 2, 2)).copy()
    h2 = gains_along_trace(chan, pos, np.zeros((5, 2)),
                           rng=np.random.default_rng(0))
    # d=15 is guaranteed LOS; even NLOS at 15 m beats LOS at 90 m
    assert (h2[:, 0] > h2[:, 1]).all()


def test_rounds_from_trace_h2_sampled_at_round_starts():
    """Non-integer round_duration/dt must not drift the h2 sample points."""
    from repro.scenarios.contacts import rounds_from_trace

    dt, delta, rounds = 4.0, 10.0, 50
    steps = int(rounds * delta / dt)
    t = np.arange(steps) * dt
    # one device moving radially: d(t) = 5 + 0.02 t  (always LOS, d <= 18)
    pos = np.stack([5.0 + 0.02 * t, np.zeros(steps)], -1)[:, None, :]
    trace = Trace(pos=pos, mes=np.zeros((steps, 2)), dt=dt)
    chan = WirelessChannel(shadow_los_db=0.0, shadow_nlos_db=0.0)
    _, _, h2 = rounds_from_trace(trace, 100.0, rounds, delta, channel=chan,
                                 rng=np.random.default_rng(0))
    # invert the LOS path loss to recover the distance actually sampled
    pl_db = -10 * np.log10(h2[:, 0])
    d_rec = 10 ** ((pl_db - 32.4 - 20 * np.log10(chan.carrier_ghz)) / 21.0)
    d_true = 5.0 + 0.02 * (np.arange(rounds) * delta)
    assert np.abs(d_rec - d_true).max() < 0.02 * dt + 1e-6


def test_gains_fast_motion_decorrelates():
    chan = WirelessChannel(seed=1)
    rng = np.random.default_rng(11)
    steps = 400

    def corr(step_len):
        walk = np.cumsum(rng.normal(0, step_len, (steps, 1, 2)), axis=0)
        pos = 500.0 + walk  # stay far from the MES so distance is ~constant
        db = 10 * np.log10(gains_along_trace(
            chan, pos, np.zeros((steps, 2)), rng=np.random.default_rng(5)))
        x = db[:, 0] - db[:, 0].mean()
        return float((x[1:] * x[:-1]).mean() / (x * x).mean())

    assert corr(1.0) > corr(200.0) + 0.3  # slow motion -> correlated shadowing


# ---------------------------------------------------------------------------
# ScenarioProvider
# ---------------------------------------------------------------------------


def test_provider_exponential_matches_legacy_contact_schedule():
    """Equivalence: the exponential scenario reproduces contact_schedule."""
    from repro.mobility import contact_schedule

    fl = FLConfig(num_devices=32, rounds=2000, mean_contact=6.0,
                  mean_intercontact=100.0, seed=3)
    zeta_l, tau_l = contact_schedule(fl, fl.rounds)
    prov = ScenarioProvider.from_config(fl)
    zeta_p, tau_p, h2 = prov.schedule()
    assert zeta_p.shape == zeta_l.shape and h2.shape == zeta_l.shape
    assert abs(zeta_p.mean() - zeta_l.mean()) / zeta_l.mean() < 0.1
    assert (abs(tau_p[zeta_p == 1].mean() - tau_l[zeta_l == 1].mean())
            / tau_l[zeta_l == 1].mean() < 0.1)
    # i.i.d. gains follow the WirelessChannel marginal
    chan = WirelessChannel(seed=100)
    ref = chan.sample_gain(zeta_l.size)
    assert abs(np.log10(h2).mean() - np.log10(ref).mean()) < 0.5


@pytest.mark.parametrize("name", ["rwp", "gauss_markov", "manhattan", "hotspot"])
def test_provider_all_models_produce_rounds(name):
    fl = FLConfig(num_devices=16, rounds=150, mobility_model=name, speed=10.0,
                  area=600.0, seed=1)
    zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
    assert zeta.shape == tau.shape == h2.shape == (150, 16)
    assert zeta.sum() > 0, name  # some contact happens
    assert ((tau > 0) == (zeta == 1)).all()
    assert (h2 > 0).all() and np.isfinite(h2).all()


def test_provider_static_model_freezes_contacts():
    """mobility_model='static' -> motionless devices: per-device contact is
    all-rounds or never, and h2 is constant over time."""
    fl = FLConfig(num_devices=24, rounds=30, mobility_model="static",
                  area=300.0, seed=3)
    zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
    per_dev = zeta.sum(0)
    assert ((per_dev == 0) | (per_dev == 30)).all()
    assert per_dev.max() == 30  # area 300 -> someone is inside comm_range
    np.testing.assert_allclose(h2, np.broadcast_to(h2[0], h2.shape), rtol=1e-6)


def test_provider_streaming_round_access():
    fl = FLConfig(num_devices=4, rounds=20)
    prov = ScenarioProvider.from_config(fl).prefetch()
    rows = list(prov)
    assert len(rows) == len(prov) == 20
    z0, t0, h0 = prov.round(7)
    np.testing.assert_array_equal(z0, rows[7][0])
    np.testing.assert_array_equal(h0, rows[7][2])


def test_provider_h2_correlated_within_contact_at_low_speed():
    """The point of position-coupling: slow devices keep a similar channel
    across consecutive contact rounds (the i.i.d. shortcut cannot)."""
    fl = FLConfig(num_devices=32, rounds=400, mobility_model="gauss_markov",
                  speed=1.0, area=400.0, round_duration=2.0, seed=2)
    zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
    both = (zeta[1:] == 1) & (zeta[:-1] == 1)
    assert both.sum() > 50
    db = 10 * np.log10(h2)
    diff_contact = np.abs(db[1:] - db[:-1])[both]
    # i.i.d. resampling baseline: shuffle rounds independently per device
    rng = np.random.default_rng(0)
    shuf = np.stack([rng.permutation(db[:, i]) for i in range(db.shape[1])], 1)
    diff_iid = np.abs(shuf[1:] - shuf[:-1])[both]
    assert diff_contact.mean() < 0.5 * diff_iid.mean()


def test_provider_from_arrays_wraps_legacy_schedule():
    zeta = np.zeros((10, 3), np.int32)
    zeta[2, 1] = 1
    tau = np.where(zeta, 4.0, 0.0).astype(np.float32)
    prov = ScenarioProvider.from_arrays(zeta, tau, channel=WirelessChannel(seed=2))
    z, t, h = prov.schedule()
    np.testing.assert_array_equal(z, zeta)
    assert h.shape == (10, 3) and (h > 0).all()


# ---------------------------------------------------------------------------
# measure_contact_stats boundary bias (satellite fix)
# ---------------------------------------------------------------------------


def test_contact_stats_drop_truncated_segments():
    # window truncates the leading contact and the trailing contact
    x = np.array([1, 1, 0, 0, 0, 1, 1, 1, 1, 0, 0, 1], bool)[:, None]
    c, g = measure_contact_stats(x, dt=1.0)
    assert c == 4.0  # only the interior contact counts
    assert g == 2.5  # interior gaps (3, 2)
    c_b, g_b = measure_contact_stats(x, dt=1.0, drop_truncated=False)
    assert c_b < c and g_b <= g  # seed estimator counts the cut pieces


def test_contact_stats_bias_on_periodic_truth():
    """RWP-like near-deterministic durations: window-cut boundary pieces
    drag the seed estimator below the true mean; censoring removes them."""
    true_c, true_g = 30, 70
    period = true_c + true_g
    rng = np.random.default_rng(0)
    one_period = np.array([True] * true_c + [False] * true_g)
    cols = [np.roll(np.tile(one_period, 6), rng.integers(period))[:500]
            for _ in range(100)]
    trace = np.stack(cols, axis=1)
    c_fix, g_fix = measure_contact_stats(trace)
    c_bias, g_bias = measure_contact_stats(trace, drop_truncated=False)
    assert c_fix == pytest.approx(true_c)  # interior segments are exact
    assert g_fix == pytest.approx(true_g)
    assert c_bias < true_c * 0.97  # cut pieces bias the seed estimator low
    assert g_bias < true_g * 0.97


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


def test_runner_consumes_trace_scenario():
    import jax

    from repro.core.runner import run_afl
    from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
    from repro.models.registry import build_model
    from repro.configs import get_config

    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    fl = FLConfig(num_devices=4, rounds=6, batch_size=8, mobility_model="rwp",
                  speed=20.0, area=300.0, seed=1)
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(64, seed=1)
    parts = dirichlet_partition(labels, 4, rho=100.0, seed=1)
    loader = DeviceLoader(
        [{"images": imgs[p], "labels": labels[p]} for p in parts], fl.batch_size
    )
    ev = dict(zip(("images", "labels"), ds.make_split(32, seed=2)))
    # pass a caller-built provider so the scenario (incl. its h2) is reused
    prov = ScenarioProvider.from_config(fl, rounds=6)
    res = run_afl(model, cfg, fl, "mads", loader, ev, rounds=6, eval_every=6,
                  schedule=prov)
    assert len(res.history["eval"]) == 1
    assert np.isfinite(res.final_eval)
    # and the default path builds the same scenario internally
    res2 = run_afl(model, cfg, fl, "mads", loader, ev, rounds=6, eval_every=6)
    assert np.isfinite(res2.final_eval)


def test_distributed_step_consumes_provider():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.distributed import (
        DistConfig, init_state, make_afl_train_step, run_afl_rounds,
    )
    from repro.core.mads import MadsController
    from repro.models.registry import build_model, demo_batch

    cfg = get_config("internlm2-1.8b").reduced().replace(num_layers=1)
    model = build_model(cfg)
    dcfg = DistConfig(num_clients=4, rounds=8, state_dtype="float32")
    step = make_afl_train_step(model, cfg, dcfg, MadsController(s=model.num_params()))
    state = init_state(model, dcfg, jax.random.key(0))

    fl = FLConfig(num_devices=4, rounds=3, mobility_model="manhattan",
                  speed=15.0, area=400.0, mean_contact=8.0, seed=4)
    prov = ScenarioProvider.from_config(fl)
    rng = np.random.default_rng(2)
    batch = {k: jnp.asarray(v) for k, v in demo_batch(cfg, 8, 16, rng).items()}
    budgets = jnp.full((4,), 100.0)
    state2, hist = run_afl_rounds(step, state, prov, lambda r: batch, budgets)
    assert len(hist) == 3
    assert int(state2.rnd) == 3
    assert all(np.isfinite(float(jnp.sum(m["energy"]))) for m in hist)
