"""Assigned-architecture configs match the assignment table exactly."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, list_configs

EXPECT = {
    # name: (family, L, d_model, H, kv, d_ff, vocab)
    "qwen2-vl-72b": ("vlm", 80, 8192, 64, 8, 29568, 152064),
    "llama3.2-3b": ("dense", 28, 3072, 24, 8, 8192, 128256),
    "internlm2-1.8b": ("dense", 24, 2048, 16, 8, 8192, 92544),
    "qwen2-7b": ("dense", 28, 3584, 28, 4, 18944, 152064),
    "qwen3-32b": ("dense", 64, 5120, 64, 8, 25600, 151936),
    "mamba2-2.7b": ("ssm", 64, 2560, 0, 0, 0, 50280),
    "whisper-large-v3": ("audio", 32, 1280, 20, 20, 5120, 51866),
    "qwen2-moe-a2.7b": ("moe", 24, 2048, 16, 16, 1408, 151936),
    "zamba2-7b": ("hybrid", 81, 3584, 32, 32, 14336, 32000),
    "qwen3-moe-30b-a3b": ("moe", 48, 2048, 32, 4, 768, 151936),
}


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_assigned_config_values(name):
    cfg = get_config(name)
    fam, nl, dm, h, kv, ff, v = EXPECT[name]
    assert cfg.family == fam
    assert cfg.num_layers == nl
    assert cfg.d_model == dm
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_signature_features():
    assert get_config("qwen2-7b").qkv_bias
    assert get_config("qwen3-32b").qk_norm
    assert get_config("qwen2-vl-72b").mrope_sections == (16, 24, 24)
    assert sum(get_config("qwen2-vl-72b").mrope_sections) == 64  # head_dim/2
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("zamba2-7b").attn_every == 6
    q2moe = get_config("qwen2-moe-a2.7b")
    assert (q2moe.num_experts, q2moe.num_experts_per_tok, q2moe.num_shared_experts) == (60, 4, 4)
    q3moe = get_config("qwen3-moe-30b-a3b")
    assert (q3moe.num_experts, q3moe.num_experts_per_tok) == (128, 8)
    assert q3moe.qk_norm
    assert get_config("llama3.2-3b").tie_embeddings


def test_paper_models_registered():
    names = list_configs()
    assert "resnet9-cifar10" in names
    assert "lanegcn-argoverse" in names


def test_long_context_support_flags():
    assert not get_config("whisper-large-v3").supports_long_context
    for n in ASSIGNED_ARCHS:
        if n != "whisper-large-v3":
            assert get_config(n).supports_long_context, n


def test_reduced_variants_are_small():
    for n in ASSIGNED_ARCHS:
        r = get_config(n).reduced()
        assert r.num_layers <= 4
        assert r.d_model <= 512
        if r.is_moe:
            assert r.num_experts <= 4


def test_resnet9_param_count_near_paper():
    from repro.models.registry import build_model

    m = build_model(get_config("resnet9-cifar10"))
    # paper: 6,568,650 parameters for ResNet-9
    assert abs(m.num_params() - 6_568_650) / 6_568_650 < 0.01
