"""Documentation integrity: markdown cross-links resolve, READMEs exist."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_markdown_links_resolve():
    """tools/check_links.py finds no broken relative links in any .md."""
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_links.py"), str(ROOT)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stderr + proc.stdout


def test_subsystem_readmes_exist():
    """The root README's architecture map points at real subsystem docs."""
    for rel in ("README.md", "src/repro/core/README.md",
                "src/repro/scenarios/README.md",
                "src/repro/experiments/README.md"):
        assert (ROOT / rel).is_file(), rel


def test_link_checker_catches_breakage(tmp_path):
    """The checker actually fails on a broken link (not vacuously green)."""
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    (tmp_path / "doc.md").write_text(
        "ok [web](https://example.com) bad [gone](missing.md)\n"
        "```\n[in code](also-missing.md)\n```\n"
    )
    (tmp_path / "ok.md").write_text("[doc](doc.md) [anchor](doc.md#sec)\n")
    errs = check_links.check_file(tmp_path / "doc.md", tmp_path)
    assert len(errs) == 1 and "missing.md" in errs[0]
    assert check_links.check_file(tmp_path / "ok.md", tmp_path) == []
