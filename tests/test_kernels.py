"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.decode_attn import decode_attn
from repro.kernels.ref import (
    decode_attn_ref,
    sparsify_ef_ref,
    sparsify_quantize_ef_ref,
    ssd_scan_ref,
)
from repro.kernels.sparsify_ef import (
    _resolve_interpret,
    sparsify_ef,
    sparsify_quantize_ef,
)
from repro.kernels.ssd_scan import ssd_scan
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n", [128, 4096, 262144, 300001, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparsify_ef_matches_ref(n, dtype):
    x = jnp.asarray(RNG.normal(0, 1, n), dtype)
    for t in [0.0, 0.3, 1.5, np.inf]:
        u, e, c = sparsify_ef(x, jnp.float32(t))
        ur, er, cr = sparsify_ef_ref(x, jnp.float32(t))
        np.testing.assert_allclose(np.asarray(u, np.float32), np.asarray(ur, np.float32))
        np.testing.assert_allclose(np.asarray(e, np.float32), np.asarray(er, np.float32))
        assert float(c) == float(cr), (n, t)


def test_sparsify_ef_reconstruction():
    x = jnp.asarray(RNG.normal(0, 1, 50000), jnp.float32)
    u, e, _ = sparsify_ef(x, jnp.float32(0.7))
    np.testing.assert_allclose(np.asarray(u + e), np.asarray(x))


def test_interpret_auto_selects_by_backend():
    """interpret=None compiles on TPU and interprets elsewhere (satellite:
    the jitted entry must not silently interpret on TPU)."""
    assert _resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert _resolve_interpret(True) is True
    assert _resolve_interpret(False) is False


@pytest.mark.parametrize("n", [128, 4096, 300001, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparsify_quantize_ef_matches_ref(n, dtype):
    """Fused sparsify+quantize+EF kernel vs oracle: upload/count bit-exact
    (shared counter dither), error within one FMA rounding."""
    x = jnp.asarray(RNG.normal(0, 1, n), dtype)
    step, levels = jnp.float32(0.01), jnp.float32(127.0)
    for t in [0.0, 0.7, np.inf]:
        u, e, c = sparsify_quantize_ef(x, jnp.float32(t), step, levels,
                                       1234, 5)
        ur, er, cr = sparsify_quantize_ef_ref(x, jnp.float32(t), step,
                                              levels, 1234, base=5)
        np.testing.assert_array_equal(
            np.asarray(u, np.float32), np.asarray(ur, np.float32))
        np.testing.assert_allclose(
            np.asarray(e, np.float32), np.asarray(er, np.float32), atol=1e-6)
        assert float(c) == float(cr), (n, t)


def test_sparsify_quantize_ef_semantics():
    """Upload values sit on the step grid; EF absorbs the quant residual."""
    x = jnp.asarray(RNG.normal(0, 1, 4096), jnp.float32)
    step = jnp.float32(0.25)
    u, e, c = sparsify_quantize_ef(x, jnp.float32(0.5), step, jnp.float32(7.0),
                                   99, 0)
    un = np.asarray(u)
    np.testing.assert_allclose(un / 0.25, np.round(un / 0.25), atol=1e-6)
    np.testing.assert_allclose(np.asarray(u + e), np.asarray(x), atol=1e-6)
    assert float(c) == float(np.sum(np.abs(np.asarray(x)) >= 0.5))
    # base offset changes the dither draw
    u2, _, _ = sparsify_quantize_ef(x, jnp.float32(0.5), step,
                                    jnp.float32(7.0), 99, 4096)
    assert not np.array_equal(un, np.asarray(u2))


def test_ops_sparsify_quantize_dispatch_nd():
    """ops wrapper accepts ND leaves and falls back to ref off-TPU."""
    x = jnp.asarray(RNG.normal(0, 1, (32, 16)), jnp.float32)
    u, e, c = ops.sparsify_quantize_ef(x, 0.5, 0.01, 127.0, 7, base=3)
    ur, er, cr = sparsify_quantize_ef_ref(x, 0.5, 0.01, 127.0, 7, base=3)
    assert u.shape == x.shape
    np.testing.assert_array_equal(np.asarray(u), np.asarray(ur))
    assert float(c) == float(cr)


@pytest.mark.parametrize(
    "b,h,kv,s,d", [(2, 8, 2, 1024, 64), (1, 4, 4, 512, 128), (2, 6, 2, 777, 64),
                   (1, 16, 2, 2048, 128)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attn_matches_ref(b, h, kv, s, d, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, h, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), dtype)
    length = int(0.7 * s)
    out = decode_attn(q, k, v, length)
    ref = decode_attn_ref(q, k, v, length)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_decode_attn_ignores_masked_tail():
    """Entries beyond `length` must not affect the result."""
    b, h, kv, s, d = 1, 4, 2, 512, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    out1 = decode_attn(q, k, v, 100)
    k2 = k.at[:, 100:].set(1e4)
    v2 = v.at[:, 100:].set(-1e4)
    out2 = decode_attn(q, k2, v2, 100)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize(
    "b,s,h,p,n,q", [(2, 256, 4, 64, 32, 64), (1, 128, 2, 32, 16, 32),
                    (1, 512, 8, 64, 64, 128)]
)
def test_ssd_scan_matches_sequential_ref(b, s, h, p, n, q):
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(0, 0.5, (b, s, h))), jnp.float32)
    bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    y, st = ssd_scan(x, a, bb, cc, chunk=q)
    yr, str_ = ssd_scan_ref(x, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), rtol=2e-4, atol=2e-4)


def test_ssd_chunked_model_path_matches_ref():
    """The pure-jnp chunked SSD used inside the Mamba2 blocks is also exact."""
    b, s, h, p, n = 2, 192, 3, 16, 8
    x = jnp.asarray(RNG.normal(0, 1, (b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(0, 0.5, (b, s, h))), jnp.float32)
    bb = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    cc = jnp.asarray(RNG.normal(0, 1, (b, s, n)), jnp.float32)
    y, st = ssd_chunked(x, a, bb, cc, 64)
    yr, str_ = ssd_scan_ref(x, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), rtol=2e-4, atol=2e-4)


def test_ops_dispatch_cpu_falls_back_to_ref():
    x = jnp.asarray(RNG.normal(0, 1, 1024), jnp.float32)
    u, e, c = ops.sparsify_ef(x, 0.5)  # auto on CPU -> ref
    ur, er, cr = sparsify_ef_ref(x, 0.5)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ur))
