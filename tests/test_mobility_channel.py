"""Mobility process + wireless channel statistics (paper §III-B, §VI)."""
import numpy as np
import pytest

from repro.channel import WirelessChannel, shannon_rate
from repro.mobility.contact import ContactProcess
from repro.mobility.waypoint import RandomWaypoint, measure_contact_stats


def test_contact_rate_matches_renewal_theory():
    """P(round overlaps a contact) ~ (c + delta)/(c + lambda)."""
    c, lam, delta = 4.0, 400.0, 10.0
    proc = ContactProcess(16, c, lam, delta, seed=1)
    zeta, tau = proc.sample_rounds(3000)
    rate = zeta.mean()
    expect = (c + delta) / (c + lam)
    assert abs(rate - expect) / expect < 0.2, (rate, expect)


def test_contact_durations_exponential_mean():
    proc = ContactProcess(8, 6.0, 100.0, 10.0, seed=2)
    zeta, tau = proc.sample_rounds(4000)
    durs = tau[zeta == 1]
    assert abs(durs.mean() - 6.0) / 6.0 < 0.15


def test_waypoint_speed_inverse_relation():
    """Fig. 4: contact & inter-contact times fall as speed rises."""
    stats = []
    for v in (5.0, 20.0):
        rw = RandomWaypoint(num_devices=12, mean_speed=v, seed=3)
        trace = rw.simulate(4000.0)
        stats.append(measure_contact_stats(trace))
    (c_slow, g_slow), (c_fast, g_fast) = stats
    assert c_fast < c_slow
    assert g_fast < g_slow


def test_pathloss_los_below_nlos():
    ch = WirelessChannel()
    assert ch.pathloss_db(50.0, True) < ch.pathloss_db(50.0, False)


def test_pathloss_matches_tr38901_formula():
    ch = WirelessChannel(carrier_ghz=3.5)
    d = 100.0
    expect = 32.4 + 21.0 * np.log10(d) + 20.0 * np.log10(3.5)
    assert abs(float(ch.pathloss_db(d, True)) - expect) < 1e-9


def test_rate_monotone_in_power():
    ch = WirelessChannel(seed=5)
    h2 = 1e-10
    rates = [shannon_rate(p, h2, 1e6) for p in (0.01, 0.05, 0.2)]
    assert rates[0] < rates[1] < rates[2]


def test_los_probability_bounds():
    ch = WirelessChannel()
    d = np.array([1.0, 18.0, 50.0, 200.0])
    p = ch.los_prob(d)
    assert (p <= 1.0).all() and (p >= 0.0).all()
    assert p[0] == 1.0 and p[-1] < p[-2]


def test_gain_sampling_reasonable_snr():
    """At p_max=0.2 W within 100 m, rates are in the Mbps regime (paper)."""
    ch = WirelessChannel(seed=6)
    r = ch.mean_rate(0.2, samples=2000)
    assert 1e5 < r < 1e9
