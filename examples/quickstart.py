"""Quickstart: mobility-aware asynchronous federated learning with MADS.

Trains the paper's CIFAR-10 setup (synthetic stand-in, reduced-width
ResNet-9) with one mobile edge server and 8 mobile devices under the
exponential contact model, using the MADS controller for dynamic
sparsification + power control.

Runtime: ~2 minutes on one CPU core.
    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
from repro.models.registry import build_model


def main():
    cfg = get_config("resnet9-cifar10").replace(d_model=8)  # reduced width
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=8, rounds=60, batch_size=16, learning_rate=0.02,
        mean_contact=6.0, mean_intercontact=30.0,  # mobility (paper §III-B)
        energy_budget=(40.0, 80.0), lyapunov_v=1e-4,  # MADS (paper §V)
        dirichlet_rho=10.0,  # non-iid level (paper §VI)
    )
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(800, seed=1)
    parts = dirichlet_partition(labels, fl.num_devices, fl.dirichlet_rho, seed=1)
    loader = DeviceLoader(
        [{"images": imgs[p], "labels": labels[p]} for p in parts], fl.batch_size
    )
    eval_batch = dict(zip(("images", "labels"), ds.make_split(256, seed=2)))

    res = run_afl(model, cfg, fl, "mads", loader, eval_batch,
                  rounds=fl.rounds, eval_every=10, log_progress=True)
    print("\nround  accuracy  cumulative-uploads  mean-k  energy(J)")
    for r, a, u, k, e in zip(res.history["round"], res.history["eval"],
                             res.history["uploads"], res.history["k_mean"],
                             res.history["energy"]):
        print(f"{r:5d}  {a:8.4f}  {u:18.0f}  {k:6.0f}  {e:9.1f}")
    print(f"\nfinal accuracy: {res.final_eval:.4f} "
          f"(params={model.num_params():,}, sparsifier adapts k per contact)")


if __name__ == "__main__":
    main()
