"""Batched serving of assigned architectures (reduced variants on CPU):
prefill a batch of prompts, then greedy-decode — the same code paths the
decode_32k / long_500k dry-runs lower at production scale (flash-decode and
SSD kernels on TPU).  Attention archs additionally run the sliding-window
ring-cache path (``--window``), where the KV cache stays at the window
size no matter how far decode runs past it.

Runtime: ~2 minutes on one CPU core.
    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --gen 24 --window 40
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import serve
from repro.models.registry import build_model

ARCHS = ["llama3.2-3b", "mamba2-2.7b", "qwen3-moe-30b-a3b"]


def run_arch(name: str, *, batch: int, prompt_len: int, gen: int,
             window: int = 0, seed: int = 0):
    """Serve one reduced arch; returns (tokens, stats)."""
    rng = np.random.default_rng(seed)
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    prompts = jax.numpy.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jax.numpy.int32
    )
    use_window = window if cfg.family in ("dense", "moe", "vlm") else 0
    toks, stats = serve(cfg, model, params, prompts, gen=gen,
                        window=use_window)
    label = f"window={use_window}" if use_window else "full-cache"
    print(f"{name:20s} family={cfg.family:6s} {label:12s} "
          f"params={model.num_params():>9,} "
          f"prefill={stats['prefill_s']:.2f}s decode={stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s) tokens={np.asarray(toks)[0].tolist()}")
    return toks, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--window", type=int, default=36,
                    help="ring-cache window for the sliding-window pass "
                         "(0 skips it; must be >= prompt-len, and < "
                         "prompt-len + gen to actually wrap)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    for name in ARCHS:
        run_arch(name, batch=args.batch, prompt_len=args.prompt_len,
                 gen=args.gen, seed=args.seed)
    if args.window:
        # the ring-cache path: window < prompt + gen forces cache wrap
        # (prefill still needs the whole prompt resident)
        if args.window < args.prompt_len:
            raise SystemExit("--window must be >= --prompt-len")
        for name in ARCHS:
            if get_config(name).family in ("dense", "moe", "vlm"):
                run_arch(name, batch=args.batch,
                         prompt_len=args.prompt_len, gen=args.gen,
                         window=args.window, seed=args.seed)


if __name__ == "__main__":
    main()
