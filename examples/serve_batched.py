"""Batched serving of assigned architectures (reduced variants on CPU):
prefill a batch of prompts, then greedy-decode — the same code paths the
decode_32k / long_500k dry-runs lower at production scale (flash-decode and
SSD kernels on TPU).

Runtime: ~2 minutes on one CPU core.
    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import serve
from repro.models.registry import build_model

ARCHS = ["llama3.2-3b", "mamba2-2.7b", "qwen3-moe-30b-a3b"]


def main():
    rng = np.random.default_rng(0)
    for name in ARCHS:
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        prompts = np.asarray(
            rng.integers(0, cfg.vocab_size, (2, 32)), np.int32
        )
        toks, stats = serve(cfg, model, params, jax.numpy.asarray(prompts), gen=8)
        print(f"{name:20s} family={cfg.family:6s} params={model.num_params():>9,} "
              f"prefill={stats['prefill_s']:.2f}s decode={stats['decode_s']:.2f}s "
              f"({stats['tok_per_s']:.1f} tok/s) tokens={np.asarray(toks)[0].tolist()}")


if __name__ == "__main__":
    main()
