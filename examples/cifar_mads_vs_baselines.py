"""Paper Fig. 8/9 style comparison: MADS vs the §VI-B benchmarks on
(synthetic) CIFAR-10 under a non-iid split and moderate mobility.

Runs through the compiled experiment engine (repro/experiments): each
policy's three seeds execute as ONE vmapped lax.scan program instead of
3 x 40 per-round dispatches, and the table reports mean±CI across seeds.

Expected ordering (paper §VI-B): optimal >= mads >= afl-spar >= {afl,
fedmobile} >> sfl-spar.  The codec policies (repro/compression) spend the
same MADS bit budget differently: mads-joint >= mads (more coordinates per
contact at a few bits each), qsgd degrades when short contacts cannot
afford dense quantisation.  Runtime: ~5 minutes on one CPU core.

    PYTHONPATH=src python examples/cifar_mads_vs_baselines.py
"""
import numpy as np

from repro.configs import FLConfig, get_config
from repro.data import SyntheticCifar, dirichlet_partition
from repro.experiments import DataShard, mean_ci, run_seed_batch
from repro.models.registry import build_model

POLICIES = ["optimal", "mads", "mads-joint", "qsgd", "fixed-kb",
            "afl-spar", "fedmobile", "afl", "sfl-spar"]
SEEDS = [0, 1, 2]


def main():
    cfg = get_config("resnet9-cifar10").replace(d_model=8)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=8, rounds=40, batch_size=16, learning_rate=0.02,
        mean_contact=2.0, mean_intercontact=30.0,  # short windows: spar matters
        energy_budget=(40.0, 80.0), dirichlet_rho=1.0,
    )
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(800, seed=1)
    parts = dirichlet_partition(labels, fl.num_devices, fl.dirichlet_rho, seed=1)
    shard = DataShard(
        [{"images": imgs[p], "labels": labels[p]} for p in parts],
        fl.batch_size,
    )
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=2)))

    print(f"{'policy':10s} {'accuracy':>15s} {'uploads':>8s} {'energy(J)':>10s}"
          f" {'Mbit/upl':>9s}")
    for pol in POLICIES:
        results = run_seed_batch(model, cfg, fl, pol, shard, ev, seeds=SEEDS,
                                 rounds=fl.rounds, eval_every=fl.rounds)
        acc, ci = mean_ci([r.final_eval for r in results])
        uploads = np.mean([r.history["uploads"][-1] for r in results])
        energy = np.mean([r.history["energy"][-1] for r in results])
        mbits = np.mean([r.history["bits_mean"][-1] for r in results]) / 1e6
        print(f"{pol:10s} {acc:9.4f}±{ci:<5.4f} {uploads:8.0f} {energy:10.1f}"
              f" {mbits:9.2f}")


if __name__ == "__main__":
    main()
