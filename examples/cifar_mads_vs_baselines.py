"""Paper Fig. 8/9 style comparison: MADS vs the §VI-B benchmarks on
(synthetic) CIFAR-10 under a non-iid split and moderate mobility.

Expected ordering (paper §VI-B): optimal >= mads >= afl-spar >= {afl,
fedmobile} >> sfl-spar.  Runtime: ~6 minutes on one CPU core.

    PYTHONPATH=src python examples/cifar_mads_vs_baselines.py
"""
from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
from repro.models.registry import build_model

POLICIES = ["optimal", "mads", "afl-spar", "fedmobile", "afl", "sfl-spar"]


def main():
    cfg = get_config("resnet9-cifar10").replace(d_model=8)
    model = build_model(cfg)
    fl = FLConfig(
        num_devices=8, rounds=40, batch_size=16, learning_rate=0.02,
        mean_contact=2.0, mean_intercontact=30.0,  # short windows: spar matters
        energy_budget=(40.0, 80.0), dirichlet_rho=1.0,
    )
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(800, seed=1)
    parts = dirichlet_partition(labels, fl.num_devices, fl.dirichlet_rho, seed=1)
    loader = DeviceLoader(
        [{"images": imgs[p], "labels": labels[p]} for p in parts], fl.batch_size
    )
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=2)))

    print(f"{'policy':10s} {'accuracy':>9s} {'uploads':>8s} {'energy(J)':>10s}")
    for pol in POLICIES:
        res = run_afl(model, cfg, fl, pol, loader, ev, rounds=fl.rounds,
                      eval_every=fl.rounds)
        print(f"{pol:10s} {res.final_eval:9.4f} "
              f"{res.history['uploads'][-1]:8.0f} {res.history['energy'][-1]:10.1f}")


if __name__ == "__main__":
    main()
