"""Paper Fig. 5 / Corollary 1: the two-sided effect of device speed.

Sweeps device speed with c = C/v, lambda = L/v (random-waypoint coupling)
and reports final accuracy next to the Corollary-1 bound (full-model gamma
form) — accuracy should peak at moderate speed while the bound dips.

Runtime: ~5 minutes on one CPU core.
    PYTHONPATH=src python examples/mobility_speed_sweep.py
"""
import numpy as np

from repro.configs import FLConfig, get_config
from repro.core import theory as T
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
from repro.models.registry import build_model

SPEEDS = [1.0, 4.0, 16.0, 48.0]
C_CONST, L_CONST = 40.0, 300.0


def main():
    cfg = get_config("resnet9-cifar10").replace(d_model=8)
    model = build_model(cfg)
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(800, seed=1)
    parts = dirichlet_partition(labels, 8, rho=100.0, seed=1)
    dev = [{"images": imgs[p], "labels": labels[p]} for p in parts]
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=2)))

    print(f"{'speed':>6s} {'contact':>8s} {'intercontact':>12s} {'acc':>7s} {'bound':>10s}")
    for v in SPEEDS:
        fl = FLConfig(
            num_devices=8, rounds=30, batch_size=16, learning_rate=0.02,
            speed=v, contact_const=C_CONST, intercontact_const=L_CONST,
            energy_budget=(40.0, 80.0),
        )
        loader = DeviceLoader(dev, fl.batch_size)
        res = run_afl(model, cfg, fl, "afl-spar", loader, ev, rounds=30, eval_every=30)
        bound = T.corollary1_bound(
            v, f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=8, rounds=30,
            rate=1e6, contact_const=C_CONST, intercontact_const=L_CONST,
            delta=10.0, s=model.num_params(), gamma_mode="model",
        )
        print(f"{v:6.1f} {C_CONST / v:8.1f} {L_CONST / v:12.1f} "
              f"{res.final_eval:7.4f} {bound:10.3f}")


if __name__ == "__main__":
    main()
