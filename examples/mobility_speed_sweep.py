"""Paper Fig. 5 / Corollary 1: the two-sided effect of device speed,
swept across the scenario engine's mobility models.

For the exponential renewal model the speed coupling is analytic
(c = C/v, lambda = L/v); for the trace models (random waypoint,
Gauss-Markov, Manhattan grid, hotspot clusters) contacts AND channel
gains emerge from the simulated motion via ``ScenarioProvider``.
Accuracy should peak at moderate speed while the Corollary-1 bound dips.

Runtime: ~5 minutes per model on one CPU core.
    PYTHONPATH=src python examples/mobility_speed_sweep.py [--models rwp,...]
"""
import argparse

import numpy as np

from repro.configs import FLConfig, get_config
from repro.core import theory as T
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, dirichlet_partition
from repro.mobility.waypoint import measure_contact_stats
from repro.models.registry import build_model
from repro.scenarios import ScenarioProvider, model_from_config

SPEEDS = [1.0, 4.0, 16.0, 48.0]
MODELS = ["exponential", "rwp", "gauss_markov", "manhattan", "hotspot"]
C_CONST, L_CONST = 40.0, 300.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma-separated subset of: " + ",".join(MODELS))
    ap.add_argument("--rounds", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config("resnet9-cifar10").replace(d_model=8)
    model = build_model(cfg)
    ds = SyntheticCifar(noise=0.3)
    imgs, labels = ds.make_split(800, seed=1)
    parts = dirichlet_partition(labels, 8, rho=100.0, seed=1)
    dev = [{"images": imgs[p], "labels": labels[p]} for p in parts]
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=2)))

    print(f"{'model':>12s} {'speed':>6s} {'contact':>8s} {'intercont':>10s} "
          f"{'uploads':>8s} {'acc':>7s} {'bound':>10s}")
    for name in args.models.split(","):
        for v in SPEEDS:
            fl = FLConfig(
                num_devices=8, rounds=args.rounds, batch_size=16,
                learning_rate=0.02, speed=v, contact_const=C_CONST,
                intercontact_const=L_CONST, energy_budget=(40.0, 80.0),
                mobility_model=name, area=600.0,
            )
            loader = DeviceLoader(dev, fl.batch_size)
            prov = ScenarioProvider.from_config(fl)
            res = run_afl(model, cfg, fl, "afl-spar", loader, ev,
                          rounds=args.rounds, eval_every=args.rounds,
                          schedule=prov)
            # realised contact statistics: analytic for the renewal model,
            # measured on a long kinematic trace for the trace models
            if name == "exponential":
                c_emp, gaps = C_CONST / v, L_CONST / v
            else:
                mdl = model_from_config(fl)
                trace = mdl.trace(4000.0, fl.mobility_dt)
                c_emp, gaps = measure_contact_stats(
                    trace.in_range(fl.comm_range), fl.mobility_dt
                )
            bound = T.corollary1_bound(
                v, f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=8,
                rounds=args.rounds, rate=1e6, contact_const=C_CONST,
                intercontact_const=L_CONST, delta=10.0,
                s=model.num_params(), gamma_mode="model",
            )
            print(f"{name:>12s} {v:6.1f} {c_emp:8.1f} {gaps:10.1f} "
                  f"{res.history['uploads'][-1]:8.0f} {res.final_eval:7.4f} "
                  f"{bound:10.3f}")


if __name__ == "__main__":
    main()
