"""Federated LLM fine-tuning with MADS sparsification — the paper's
technique applied to an assigned architecture (reduced InternLM2).

20 mobile devices hold disjoint synthetic token streams; cumulative
gradients are top-k-sparsified per contact (sampled-quantile thresholding,
the distributed-mode operator) under the MADS energy controller.

Runtime: ~4 minutes on one CPU core.
    PYTHONPATH=src python examples/federated_llm_finetune.py
"""
import numpy as np

from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticTokens
from repro.models.registry import build_model


def main():
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} (reduced) params={model.num_params():,}")
    fl = FLConfig(
        num_devices=8, rounds=40, batch_size=8, learning_rate=0.05,
        mean_contact=4.0, mean_intercontact=30.0,
        energy_budget=(40.0, 80.0), sparsifier="sampled",
    )
    ds = SyntheticTokens(vocab_size=cfg.vocab_size, seed=3)
    data = ds.make_split(400, 32, seed=4)
    order = np.random.default_rng(0).permutation(400)
    chunks = np.array_split(order, fl.num_devices)
    loader = DeviceLoader(
        [{k: v[c] for k, v in data.items()} for c in chunks], fl.batch_size
    )
    ev = ds.make_split(64, 32, seed=5)

    res = run_afl(model, cfg, fl, "mads", loader, ev, rounds=fl.rounds,
                  eval_every=10, log_progress=True)
    print("\nround  eval-loss  mean-k(of %d)" % model.num_params())
    for r, l, k in zip(res.history["round"], res.history["eval"],
                       res.history["k_mean"]):
        print(f"{r:5d}  {l:9.4f}  {k:10.0f}")
    drop = res.history["eval"][0] - res.history["eval"][-1]
    print(f"\nloss improvement over federation: {drop:.4f}")


if __name__ == "__main__":
    main()
