"""Paper Figs. 10-11: Argoverse-style trajectory prediction (LaneGCN-lite).

ADE (average displacement error) for MADS vs benchmarks, and vs speed.
"""
from __future__ import annotations

from benchmarks.common import csv_row, run_policy, trajectory_federation

ROUNDS = 40


def fig10_policies():
    cfg, model, dev, ev = trajectory_federation()
    rows = []
    for pol in ("mads", "afl-spar", "afl", "optimal"):
        accs, wall = [], 0.0
        for seed in (0, 1, 2):
            res, w = run_policy(cfg, model, dev, ev, pol, ROUNDS,
                                learning_rate=0.1, mean_contact=2.0,
                                energy_budget=(3.0, 6.0), seed=seed)
            accs.append(res.final_eval)
            wall += w
        import numpy as _np
        res_ade = _np.mean(accs)
        rows.append(csv_row(
            f"fig10_{pol}", wall / (3 * ROUNDS) * 1e6,
            f"ade={res_ade:.4f}±{_np.std(accs):.3f}"
        ))
    return rows


def fig11_speed():
    cfg, model, dev, ev = trajectory_federation()
    rows = []
    for v in (2.0, 20.0):
        res, wall = run_policy(
            cfg, model, dev, ev, "mads", ROUNDS, learning_rate=0.05,
            speed=v, contact_const=40.0, intercontact_const=300.0,
        )
        rows.append(csv_row(
            f"fig11_v{v:g}_mads", wall / ROUNDS * 1e6, f"ade={res.final_eval:.4f}"
        ))
    return rows


def run():
    return fig10_policies() + fig11_speed()
