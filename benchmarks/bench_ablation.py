"""Ablations beyond the paper's figures.

ablation_error_feedback   MADS with vs without the error-feedback memory
                          e_n under tight contact windows (heavy
                          sparsification) — quantifies how much of
                          Algorithm 1's robustness comes from the memory.
ablation_sparsifier       exact vs sampled-quantile thresholding: the
                          distributed-mode operator should not change the
                          outcome materially.
"""
from __future__ import annotations

from benchmarks.common import cifar_federation, csv_row, run_policy

ROUNDS = 30


def ablation_error_feedback():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for pol in ("mads", "mads-noef"):
        res, wall = run_policy(
            cfg, model, dev, ev, pol, 60, mean_contact=0.5, bandwidth=2e4,
        )  # ~5% of coordinates per window: the memory must carry the rest
        rows.append(csv_row(
            f"ablation_ef_{pol}", wall / 60 * 1e6,
            f"acc={res.final_eval:.4f};k_mean={res.history['k_mean'][-1]:.0f}",
        ))
    return rows


def ablation_sparsifier():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for method in ("exact", "sampled"):
        res, wall = run_policy(
            cfg, model, dev, ev, "mads", ROUNDS, sparsifier=method
        )
        rows.append(csv_row(
            f"ablation_sparsifier_{method}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f}",
        ))
    return rows


def ablation_value_bits():
    """Beyond-paper: quantized upload values (u in Proposition 1).

    u=8 buys k* ~ (32+log2 s)/(8+log2 s) = 1.9x more coordinates per contact
    window; the quantisation residual goes into the error memory."""
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for u in (32, 8):
        res, wall = run_policy(
            cfg, model, dev, ev, "mads", 40, mean_contact=0.5, bandwidth=2e4,
            value_bits=u,
        )
        rows.append(csv_row(
            f"ablation_u{u}", wall / 40 * 1e6,
            f"acc={res.final_eval:.4f};k_mean={res.history['k_mean'][-1]:.0f}",
        ))
    return rows


def run():
    return ablation_error_feedback() + ablation_sparsifier() + ablation_value_bits()
