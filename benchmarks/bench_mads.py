"""Paper Figs. 6-9: MADS vs benchmarks.

fig6_7_v      accuracy + energy vs Lyapunov weight V (Figs. 6-7)
fig8_noniid   policies at different non-iid levels rho (Fig. 8)
fig9_speed    policies at different device speeds (Fig. 9)
"""
from __future__ import annotations

from benchmarks.common import cifar_federation, csv_row, run_policy

ROUNDS = 30
POLICIES = ("mads", "afl-spar", "afl", "fedmobile", "sfl-spar", "optimal")


def fig6_7_v():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for v in (1e-6, 1e-4, 1e-2):
        res, wall = run_policy(cfg, model, dev, ev, "mads", ROUNDS, lyapunov_v=v)
        rows.append(csv_row(
            f"fig6_7_v{v:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};energyJ={res.history['energy'][-1]:.1f}",
        ))
    return rows


def fig8_noniid():
    # tight energy budgets: the paper's regime where pacing (MADS queues)
    # beats spend-then-stall (energy-capped baselines)
    rows = []
    for rho in (0.1, 1.0, 100.0):
        cfg, model, dev, ev = cifar_federation(rho=rho)
        for pol in POLICIES:
            res, wall = run_policy(cfg, model, dev, ev, pol, ROUNDS,
                                   energy_budget=(3.0, 6.0))
            rows.append(csv_row(
                f"fig8_rho{rho:g}_{pol}", wall / ROUNDS * 1e6,
                f"acc={res.final_eval:.4f}",
            ))
    return rows


def fig9_speed():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for v in (2.0, 20.0):
        for pol in ("mads", "afl-spar", "afl"):
            accs, ups, wall = [], [], 0.0
            for seed in (0, 1, 2):  # average out schedule/channel noise
                res, w = run_policy(
                    cfg, model, dev, ev, pol, ROUNDS, energy_budget=(3.0, 6.0),
                    speed=v, contact_const=40.0, intercontact_const=300.0,
                    seed=seed,
                )
                accs.append(res.final_eval)
                ups.append(res.history["uploads"][-1])
                wall += w
            import numpy as _np

            rows.append(csv_row(
                f"fig9_v{v:g}_{pol}", wall / (3 * ROUNDS) * 1e6,
                f"acc={_np.mean(accs):.4f}±{_np.std(accs):.3f};"
                f"uploads={_np.mean(ups):.0f}",
            ))
    return rows


def run():
    return fig6_7_v() + fig8_noniid() + fig9_speed()
