"""Compression subsystem benchmarks (repro/compression).

Two families of rows:

* ``sqef_*`` — the fused sparsify+quantize+EF op vs the naive three-pass
  jnp pipeline (mask pass, quantise pass, error pass + count reduce) at
  ResNet-9 size.  CPU wall times are indicative; the HBM-traffic argument
  (1 read + 2 writes vs 3 reads + 3 writes) is in
  ``repro/kernels/sparsify_ef.py`` — TPU is the target.
* ``codec_*`` — accuracy-vs-bits on the synthetic CIFAR federation: the
  same MADS power policy spending the same contact budgets through each
  codec (top-k@32, joint (k,b), QSGD, fixed-(k,b)); derived column reports
  final eval + mean realised upload bits, i.e. the paper-table the joint
  codec is supposed to win.

``python -m benchmarks.bench_compression --smoke`` shrinks both for CI.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def _three_pass(x, t, step, levels, seed):
    """The unfused pipeline a naive port would write."""
    from repro.compression.quant import dither_u01

    mask = jnp.abs(x) >= t                                   # pass 1
    upload = jnp.where(mask, x, 0.0)
    u = dither_u01(jnp.asarray(seed), jnp.arange(x.size))    # pass 2
    upload = jnp.clip(jnp.floor(upload / step + u), -levels, levels) * step
    upload = jnp.where(mask, upload, 0.0)
    error = x - upload                                       # pass 3
    return upload, error, jnp.sum(mask).astype(jnp.float32)  # + reduce


def micro_rows(smoke: bool):
    from repro.kernels.ref import sparsify_quantize_ef_ref

    rng = np.random.default_rng(0)
    n = 500_000 if smoke else 6_568_650  # ResNet-9 size
    x = jnp.asarray(rng.normal(0, 1, n), jnp.float32)
    args = (x, jnp.float32(0.5), jnp.float32(0.01), jnp.float32(127.0), 7)
    tag = f"{n/1e6:.1f}M"
    three = _time(jax.jit(_three_pass), *args)
    fused = _time(jax.jit(sparsify_quantize_ef_ref), *args)
    return [
        csv_row(f"sqef_three_pass_{tag}", three, "impl=jnp_3pass"),
        csv_row(f"sqef_fused_{tag}", fused,
                f"impl=fused,speedup={three / max(fused, 1e-9):.2f}x"),
    ]


def codec_rows(smoke: bool):
    from repro.configs import FLConfig, get_config
    from repro.experiments import DataShard, run_afl_scanned
    from repro.launch.train import build_device_data
    from repro.models.registry import build_model

    cfg = get_config("resnet9-cifar10").replace(d_model=4 if smoke else 8)
    model = build_model(cfg)
    rounds = 6 if smoke else 40
    fl = FLConfig(
        num_devices=4 if smoke else 8, rounds=rounds, batch_size=8,
        learning_rate=0.02, mean_contact=2.0, mean_intercontact=30.0,
        energy_budget=(40.0, 80.0),
    )
    dev, ev = build_device_data(cfg, fl, train_n=160 if smoke else 800,
                                eval_n=64 if smoke else 256, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    rows = []
    for policy in ("mads", "mads-joint", "mads-joint-pl", "qsgd", "fixed-kb"):
        flp = fl
        name = policy
        if policy == "mads-joint-pl":  # per-layer (k_l, b_l) budgets
            import dataclasses

            policy = "mads-joint"
            flp = dataclasses.replace(fl, per_layer_budget=True)
        t0 = time.time()
        res = run_afl_scanned(model, cfg, flp, policy, shard, ev,
                              rounds=rounds, eval_every=rounds)
        us = (time.time() - t0) / rounds * 1e6
        rows.append(csv_row(
            f"codec_{name}", us,
            f"eval={res.final_eval:.4f},bits_mean={res.history['bits_mean'][-1]:.0f},"
            f"k_mean={res.history['k_mean'][-1]:.0f}",
        ))
    return rows


def mesh_rows(smoke: bool):
    """Sharded parity row: the pjit AFL step with the joint codec on a
    simulated (mesh_devices, 1) client mesh vs the same step unsharded —
    realised bits must agree (codec thresholds are shard-safe)."""
    from repro.configs import FLConfig, get_config
    from repro.core import baselines as BL
    from repro.core.distributed import (
        DistConfig, client_state_shardings, init_state, make_afl_train_step,
        run_afl_rounds,
    )
    from repro.core.runner import build_provider, sample_budgets
    from repro.experiments import DataShard
    from repro.launch.mesh import make_client_mesh
    from repro.launch.train import build_device_data
    from repro.models.registry import build_model

    cfg = get_config("resnet9-cifar10").replace(d_model=4)
    model = build_model(cfg)
    rounds = 3 if smoke else 10
    fl = FLConfig(num_devices=4, rounds=rounds, batch_size=8,
                  mean_contact=4.0, mean_intercontact=20.0)
    dev, _ = build_device_data(cfg, fl, train_n=160, eval_n=32, seed=0)
    shard = DataShard(dev, fl.batch_size, seed=0)
    key = shard.seed_key(0)
    policy = BL.ALL["mads-joint"](model.num_params(), fl)
    dcfg = DistConfig(num_clients=fl.num_devices, rounds=rounds,
                      state_dtype="float32")
    step = jax.jit(make_afl_train_step(model, cfg, dcfg, policy.controller,
                                       compressor=policy.compressor))
    mesh = make_client_mesh(fl.num_devices)

    def batch_fn(r):
        return jax.tree.map(lambda v: v.reshape((-1,) + v.shape[2:]),
                            shard.traced_batch(key, r))

    def run(use_mesh):
        provider = build_provider(fl, "mads-joint", None, rounds, 0)
        state = init_state(model, dcfg, jax.random.key(0))
        if use_mesh:
            # commit the client axis to the mesh's data axis — a bare
            # `with mesh:` around jit would keep everything on one device
            state = jax.device_put(state, client_state_shardings(state, mesh))
        budgets = sample_budgets(fl, 0)
        t0 = time.time()
        _, hist = run_afl_rounds(step, state, provider, batch_fn, budgets,
                                 rounds=rounds)
        wall = (time.time() - t0) / rounds * 1e6
        bits = np.stack([np.asarray(m["bits"]) for m in hist])
        return wall, bits

    us_1, bits_1 = run(False)
    if mesh is None:
        return [csv_row("dist_joint_mesh1", us_1,
                        "impl=unsharded,mesh_unavailable")]
    ndev = int(np.prod(mesh.devices.shape))
    us_m, bits_m = run(True)
    agree = bool(np.array_equal(bits_1, bits_m))
    return [
        csv_row("dist_joint_mesh1", us_1, "impl=unsharded"),
        csv_row(f"dist_joint_mesh{ndev}", us_m,
                f"impl=client_mesh,bits_agree={agree}"),
    ]


def run(smoke: bool = False, mesh: int = 0):
    rows = micro_rows(smoke) + codec_rows(smoke)
    if mesh > 1:
        rows += mesh_rows(smoke)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny model, few rounds")
    ap.add_argument("--mesh", type=int, default=0,
                    help=">1: force this many simulated host devices and "
                         "add the sharded-vs-unsharded parity rows")
    args = ap.parse_args()
    if args.mesh > 1:
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(args.mesh)
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, mesh=args.mesh):
        print(row)


if __name__ == "__main__":
    main()
