"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads the JSONL records produced by ``python -m repro.launch.dryrun`` and
emits one CSV row per (arch, shape, mesh) with the three terms and the
bottleneck.  Prefers the post-§Perf ``dryrun_final.jsonl`` (both meshes in
one file); falls back to the original baseline files.  If nothing exists
(fresh checkout) it reports that the sweep must be run first rather than
failing the bench harness.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row


def _load():
    recs = []
    if os.path.exists("dryrun_final.jsonl"):
        recs += [json.loads(l) for l in open("dryrun_final.jsonl")]
    else:
        for f in ("dryrun_baseline.jsonl", "dryrun_multipod.jsonl"):
            if os.path.exists(f):
                recs += [json.loads(l) for l in open(f)]
    if os.path.exists("dryrun_perf.jsonl"):
        recs += [json.loads(l) for l in open("dryrun_perf.jsonl")]
    return recs


def run():
    recs = _load()
    if not recs:
        return [csv_row("roofline", 0.0, "missing: run repro.launch.dryrun --all")]
    best = {}
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        key = (mesh, r["arch"], r["shape"], r.get("tag", "baseline"))
        best[key] = r
    rows = []
    for (mesh, arch, shape, tag), r in sorted(best.items()):
        name = f"roofline_{mesh}_{arch}_{shape}"
        if tag not in ("baseline", "final"):
            name += f"_{tag}"
        if r["status"] == "skipped":
            rows.append(csv_row(name, 0.0, "skipped"))
            continue
        if r["status"] != "ok":
            rows.append(csv_row(name, 0.0, f"error={r.get('error', '?')[:60]}"))
            continue
        rf = r["roofline"]
        dominant = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        rows.append(csv_row(
            name,
            r.get("compile_s", 0.0) * 1e6,
            f"tc={rf['t_compute']:.3e};tm={rf['t_memory']:.3e};"
            f"tx={rf['t_collective']:.3e};bound={rf['bottleneck']};"
            f"useful={rf['useful_ratio']:.2f};step_s={dominant:.3e}",
        ))
    return rows
