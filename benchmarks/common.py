"""Shared benchmark harness: a small, fast federation (CPU-sized ResNet on
synthetic CIFAR) mirroring the paper's §VI setup at reduced scale.

Every figure-benchmark perturbs exactly one system variable (contact time,
inter-contact time, speed, V, rho, policy) — like the paper's ablations —
and reports time-per-round plus the figure's derived quantity.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticCifar, SyntheticTrajectories, dirichlet_partition
from repro.models.registry import build_model

BASE_FL = dict(
    num_devices=8,
    rounds=40,
    batch_size=16,
    learning_rate=0.02,
    mean_contact=6.0,
    mean_intercontact=30.0,
    energy_budget=(40.0, 80.0),
    lyapunov_v=1e-4,
)


def cifar_federation(rho: float = 100.0, devices: int = 8, seed: int = 11,
                     width: int = 8, train_n: int = 800):
    cfg = get_config("resnet9-cifar10").replace(d_model=width)
    model = build_model(cfg)
    ds = SyntheticCifar(noise=0.3, seed=seed)
    imgs, labels = ds.make_split(train_n, seed=seed + 1)
    parts = dirichlet_partition(labels, devices, rho=rho, seed=seed)
    dev = [{"images": imgs[p], "labels": labels[p]} for p in parts]
    ev = dict(zip(("images", "labels"), ds.make_split(256, seed=seed + 2)))
    return cfg, model, dev, ev


def trajectory_federation(devices: int = 8, seed: int = 21, train_n: int = 800):
    cfg = get_config("lanegcn-argoverse").replace(d_model=32, d_ff=64)
    model = build_model(cfg)
    ds = SyntheticTrajectories(seed=seed)
    data = ds.make_split(train_n, seed=seed + 1)
    order = np.random.default_rng(seed).permutation(train_n)
    chunks = np.array_split(order, devices)
    dev = [{k: v[c] for k, v in data.items()} for c in chunks]
    ev = ds.make_split(256, seed=seed + 2)
    return cfg, model, dev, ev


def run_policy(cfg, model, dev, ev, policy: str, rounds: int, **fl_over):
    params = dict(BASE_FL)
    params.update(fl_over)
    params["rounds"] = rounds
    params["num_devices"] = len(dev)
    fl = FLConfig(**params)
    loader = DeviceLoader(dev, fl.batch_size, seed=fl.seed)
    t0 = time.time()
    res = run_afl(model, cfg, fl, policy, loader, ev, rounds=rounds,
                  eval_every=max(rounds // 2, 1))
    # dispatch is async: block on the final state so wall covers the work
    jax.block_until_ready(res.state)
    wall = time.time() - t0
    return res, wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
