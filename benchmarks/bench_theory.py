"""Theory validation: Lemma 2/3 Monte-Carlo vs closed forms, Corollary 1
U-shape, Theorem 2 monotonicities (the paper's analytical claims)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import theory as T
from repro.mobility.contact import ContactProcess


def lemma2():
    rows = []
    for c, lam in ((4.0, 40.0), (8.0, 100.0)):
        t0 = time.time()
        proc = ContactProcess(8, c, lam, 10.0, seed=0)
        zeta, _ = proc.sample_rounds(3000)
        kappa = np.zeros(8, int)
        sq = []
        for r in range(1, 3001):
            up = zeta[r - 1] == 1
            sq.append((r - kappa)[up])
            kappa[up] = r
        mc = float(np.mean(np.concatenate(sq).astype(float) ** 2))
        bound = T.staleness_second_moment(c, lam, 10.0)
        rows.append(csv_row(
            f"lemma2_c{c:g}_l{lam:g}", (time.time() - t0) * 1e6,
            f"mc={mc:.2f};bound={bound:.2f};bound_plus_round={(bound**0.5+1)**2:.2f}",
        ))
    return rows


def lemma3():
    t0 = time.time()
    s, u, rate, c = 4096, 32, 2e4, 3.0
    rng = np.random.default_rng(0)
    import jax.numpy as jnp

    from repro.core import sparsify as SP

    x = jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    errs = []
    for _ in range(100):
        tau = rng.exponential(c)
        k = min(tau * rate / (u + np.log2(s)), s)
        _, err, _ = SP.sparsify_topk(x, float(k), method="exact")
        errs.append(float(jnp.sum(err**2)) / float(jnp.sum(x**2)))
    literal = 1 - T.gamma(rate, c, s, u)
    corrected = T.expected_error_fraction(rate, c, s, u)
    return [csv_row(
        "lemma3_error_fraction", (time.time() - t0) * 1e6,
        f"mc={np.mean(errs):.4f};paper_literal={literal:.2e};corrected={corrected:.4f}",
    )]


def corollary1():
    t0 = time.time()
    args = dict(
        f0_gap=1.0, big_l=1.0, sigma=1.0, g2=1.0, n=20, rounds=500,
        rate=1e6, contact_const=200.0, intercontact_const=4000.0,
        delta=10.0, s=100_000, gamma_mode="model",
    )
    grid = np.linspace(1, 120, 120)
    vals = [T.corollary1_bound(v, **args) for v in grid]
    vstar = float(grid[int(np.argmin(vals))])
    return [csv_row(
        "corollary1_ushape", (time.time() - t0) * 1e6,
        f"vstar={vstar:.1f};b_low={vals[0]:.3f};b_min={min(vals):.3f};b_high={vals[-1]:.3f}",
    )]


def run():
    return lemma2() + lemma3() + corollary1()
