"""Streaming ingestion server throughput (repro/serve).

serve_fused        fused batched decompress+aggregate vs the per-upload
                   loop baseline (the acceptance point: N=1e4 queued
                   uploads, >= 3x the loop's uploads/sec on CPU)
serve_scatter      the O(B*K) scatter aggregation kernel at the same
                   point (same math up to float summation order)
serve_staleness    hinge staleness-weighted mixing at the fused point
                   (the discount costs nothing — same fused program)

``--smoke`` (benchmarks.run) keeps one reduced fused row — the
committed-baseline set gated by ``tools/bench_compare.py`` in CI
(BENCH_serve.json); ``uploads_per_s`` is the higher-is-better metric.
"""
from __future__ import annotations

from benchmarks.common import csv_row
from repro.launch.soak import run_soak


def _row(name: str, res: dict) -> str:
    derived = f"uploads_per_s={res['fused_per_s']:.0f}"
    if "speedup_vs_loop" in res:
        derived += (f";loop_per_s={res['loop_per_s']:.0f}"
                    f";speedup_vs_loop={res['speedup_vs_loop']:.1f}x")
    rej = res["snapshot"]["counters"]["rejected"]
    der = res["snapshot"]["counters"]["deferred"]
    derived += f";rejected={rej:.0f};deferred={der:.0f}"
    us = res["fused_wall_s"] / max(res["uploads"], 1) * 1e6
    return csv_row(name, us, derived)


def serve_fused(smoke: bool = False):
    n, b, s, k = (1500, 128, 2048, 128) if smoke else (10_000, 256, 4096, 256)
    res = run_soak(uploads=n, batch=b, s=s, max_k=k, codec="topk",
                   mode="parity")
    return [_row(f"serve_fused_topk_n{n}_b{b}_s{s}", res)]


def serve_scatter():
    n, b, s, k = 10_000, 256, 4096, 256
    res = run_soak(uploads=n, batch=b, s=s, max_k=k, codec="topk",
                   mode="scatter")
    return [_row(f"serve_scatter_topk_n{n}_b{b}_s{s}", res)]


def serve_staleness():
    n, b, s, k = 10_000, 256, 4096, 256
    res = run_soak(uploads=n, batch=b, s=s, max_k=k, codec="topk",
                   staleness_family="hinge", baseline=False)
    return [_row(f"serve_fused_hinge_n{n}_b{b}_s{s}", res)]


def run(smoke: bool = False):
    if smoke:  # CI: the committed-baseline gated row only
        return serve_fused(smoke=True)
    return serve_fused() + serve_scatter() + serve_staleness()
