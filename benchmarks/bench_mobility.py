"""Paper Figs. 2-5: mobility's effect on AFL convergence, plus the
scenario-engine vectorization speedups.

fig2_contact        accuracy vs mean contact time (Fig. 2)
fig3_intercontact   accuracy vs mean inter-contact time (Fig. 3)
fig4_waypoint       random-waypoint c, lambda vs speed (Fig. 4)
fig5_speed          accuracy vs device speed, U-shape (Fig. 5)
vectorized_speedup  scenario engine vs the seed Python-loop paths
scenario_models     per-model (zeta, tau, h2) generation cost
jax_scenario_speedup  device-resident (jax) generation vs the NumPy oracle

``--smoke`` (benchmarks.run) keeps the scenario-engine rows (N=512 for
the jax-vs-numpy differential) and skips the federated-training figure
sweeps; the smoke rows are the committed-baseline set gated by
``tools/bench_compare.py`` in CI (BENCH_mobility.json).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import cifar_federation, csv_row, run_policy
from repro.mobility.contact import ContactProcess
from repro.mobility.waypoint import RandomWaypoint, measure_contact_stats

ROUNDS = 30


def fig2_contact():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for c in (1.0, 4.0, 16.0):
        res, wall = run_policy(cfg, model, dev, ev, "afl-spar", ROUNDS,
                               mean_contact=c)
        rows.append(csv_row(
            f"fig2_contact_c{c:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};uploads={res.history['uploads'][-1]:.0f}",
        ))
    return rows


def fig3_intercontact():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for lam in (10.0, 40.0, 160.0):
        res, wall = run_policy(cfg, model, dev, ev, "afl-spar", ROUNDS,
                               mean_intercontact=lam)
        rows.append(csv_row(
            f"fig3_intercontact_l{lam:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};theta={res.history['theta_mean'][-1]:.2f}",
        ))
    return rows


def fig4_waypoint():
    rows = []
    for v in (5.0, 10.0, 20.0):
        rw = RandomWaypoint(num_devices=10, mean_speed=v, seed=4)
        import time

        t0 = time.time()
        trace = rw.simulate(3000.0)
        wall = time.time() - t0
        c, lam = measure_contact_stats(trace)
        rows.append(csv_row(
            f"fig4_waypoint_v{v:g}", wall * 1e6,
            f"contact={c:.1f}s;intercontact={lam:.1f}s;cv={c*v:.0f};lv={lam*v:.0f}",
        ))
    return rows


def fig5_speed():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for v in (2.0, 8.0, 32.0):
        res, wall = run_policy(
            cfg, model, dev, ev, "afl-spar", ROUNDS,
            speed=v, contact_const=40.0, intercontact_const=300.0,
        )
        rows.append(csv_row(
            f"fig5_speed_v{v:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};uploads={res.history['uploads'][-1]:.0f}",
        ))
    return rows


def vectorized_speedup():
    """Scenario-engine vectorization vs the seed Python-loop paths at
    N=100, rounds=1000 (delta=10 s, dt=1 s -> 10k kinematic steps)."""
    from repro.scenarios import RandomWaypointModel

    rows = []
    n, rounds, delta = 100, 1000, 10.0

    def best(fn, reps=5):  # min over repeats rejects scheduler noise
        fn()  # warm
        return min(
            (lambda t0: (fn(), time.time() - t0)[1])(time.time())
            for _ in range(reps)
        )

    # (a) renewal contact sampling: batched vs per-device while-loop
    proc = ContactProcess(n, 4.0, 400.0, delta, seed=1)
    t_vec = best(lambda: proc.sample_rounds(rounds))
    t_loop = best(lambda: proc.sample_rounds_loop(rounds), reps=3)
    rows.append(csv_row(
        "contact_sampling_vectorized", t_vec * 1e6,
        f"loop_us={t_loop * 1e6:.0f};speedup={t_loop / t_vec:.1f}x",
    ))

    # (b) trace generation: leg-based RWP vs the seed per-step loop
    duration = rounds * delta
    seed_rw = RandomWaypoint(num_devices=n, mean_speed=10.0, seed=4)
    t_seed = best(lambda: seed_rw.simulate(duration), reps=3)
    vec_rw = RandomWaypointModel(num_devices=n, mean_speed=10.0, seed=4,
                                 mobile_mes=True)
    t_vec = best(lambda: vec_rw.trace(duration))
    rows.append(csv_row(
        "rwp_trace_vectorized", t_vec * 1e6,
        f"seed_loop_us={t_seed * 1e6:.0f};speedup={t_seed / t_vec:.1f}x",
    ))
    return rows


def scenario_models():
    """End-to-end (zeta, tau, h2) generation cost per mobility model."""
    from repro.configs import FLConfig
    from repro.scenarios import ScenarioProvider

    rows = []
    for name in ("exponential", "rwp", "gauss_markov", "manhattan", "hotspot"):
        fl = FLConfig(num_devices=100, rounds=1000, mobility_model=name,
                      speed=10.0)
        t0 = time.time()
        zeta, tau, h2 = ScenarioProvider.from_config(fl).schedule()
        wall = time.time() - t0
        rows.append(csv_row(
            f"scenario_{name}", wall * 1e6,
            f"contact_rate={zeta.mean():.4f};"
            f"tau={float(tau[zeta == 1].mean()) if zeta.any() else 0:.1f}s",
        ))
    return rows


def jax_scenario_speedup(smoke: bool = False):
    """End-to-end schedule generation: jax backend vs the NumPy oracle.

    Times ``ScenarioProvider.from_config(...).schedule()`` — trace,
    contact extraction, round mapping, and channel gains — through both
    backends at the same scenario point.  The jax rows are steady-state
    (the first build compiles; a second provider on a fresh seed reuses
    the cached program — the seed enters through the PRNG key, not the
    static model).  ``cells_per_s`` (rounds x N per second) is the gated
    higher-is-better throughput metric.

    Full mode runs the acceptance point N=1e5, where the oracle RWP's
    per-device interp loop dominates; smoke (CI) runs N=512.
    """
    import jax

    from repro.configs import FLConfig
    from repro.scenarios import ScenarioProvider

    n, rounds = (512, 60) if smoke else (100_000, 100)
    rows = []
    for name in ("rwp", "gauss_markov"):
        fl = FLConfig(num_devices=n, rounds=rounds, mobility_model=name,
                      speed=10.0, area=2000.0, seed=0)
        t0 = time.time()
        ScenarioProvider.from_config(fl).schedule()
        np_wall = time.time() - t0

        flj = dataclasses.replace(fl, scenario_backend="jax")
        jax.block_until_ready(
            ScenarioProvider.from_config(flj).schedule())  # compile
        t0 = time.time()
        jax.block_until_ready(
            ScenarioProvider.from_config(flj, seed=1).schedule())
        jx_wall = time.time() - t0

        cells = rounds * n
        rows.append(csv_row(
            f"jax_scenario_{name}_n{n}", jx_wall * 1e6,
            f"cells_per_s={cells / jx_wall:.0f}"
            f";numpy_wall_s={np_wall:.3f}"
            f";speedup_vs_numpy={np_wall / jx_wall:.1f}x",
        ))
    return rows


def rwp_kernel(smoke: bool = False):
    """The jitted RWP position kernel alone (``_rwp_positions``): the
    bucketed uniform-grid leg lookup replacing the vmapped per-device
    ``searchsorted`` (the PR-9 follow-up).  ``cells_per_s`` (steps x N per
    second, steady-state) is the gated metric; the searchsorted
    formulation measured ~1.5-1.7x slower at both points."""
    import jax

    from repro.scenarios.jax_kinematics import _rwp_positions

    n, steps = (512, 600) if smoke else (10_000, 1000)
    f = jax.jit(_rwp_positions, static_argnums=(1, 2, 3, 4, 5, 6))
    args = (steps, 1.0, n, 2000.0, 10.0, 5.0)
    jax.block_until_ready(f(jax.random.PRNGKey(0), *args))  # compile
    t0 = time.time()
    reps = 3
    for r in range(reps):
        jax.block_until_ready(f(jax.random.PRNGKey(1 + r), *args))
    wall = (time.time() - t0) / reps
    return [csv_row(
        f"jax_rwp_kernel_n{n}", wall * 1e6,
        f"cells_per_s={steps * n / wall:.0f}",
    )]


def run(smoke: bool = False):
    scenario = (fig4_waypoint() + vectorized_speedup() + scenario_models()
                + rwp_kernel(smoke=smoke) + jax_scenario_speedup(smoke=smoke))
    if smoke:  # CI: scenario-engine rows only, no federated training
        return scenario
    return fig2_contact() + fig3_intercontact() + fig5_speed() + scenario
