"""Paper Figs. 2-5: mobility's effect on AFL convergence.

fig2_contact        accuracy vs mean contact time (Fig. 2)
fig3_intercontact   accuracy vs mean inter-contact time (Fig. 3)
fig4_waypoint       random-waypoint c, lambda vs speed (Fig. 4)
fig5_speed          accuracy vs device speed, U-shape (Fig. 5)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cifar_federation, csv_row, run_policy
from repro.mobility.waypoint import RandomWaypoint, measure_contact_stats

ROUNDS = 30


def fig2_contact():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for c in (1.0, 4.0, 16.0):
        res, wall = run_policy(cfg, model, dev, ev, "afl-spar", ROUNDS,
                               mean_contact=c)
        rows.append(csv_row(
            f"fig2_contact_c{c:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};uploads={res.history['uploads'][-1]:.0f}",
        ))
    return rows


def fig3_intercontact():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for lam in (10.0, 40.0, 160.0):
        res, wall = run_policy(cfg, model, dev, ev, "afl-spar", ROUNDS,
                               mean_intercontact=lam)
        rows.append(csv_row(
            f"fig3_intercontact_l{lam:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};theta={res.history['theta_mean'][-1]:.2f}",
        ))
    return rows


def fig4_waypoint():
    rows = []
    for v in (5.0, 10.0, 20.0):
        rw = RandomWaypoint(num_devices=10, mean_speed=v, seed=4)
        import time

        t0 = time.time()
        trace = rw.simulate(3000.0)
        wall = time.time() - t0
        c, lam = measure_contact_stats(trace)
        rows.append(csv_row(
            f"fig4_waypoint_v{v:g}", wall * 1e6,
            f"contact={c:.1f}s;intercontact={lam:.1f}s;cv={c*v:.0f};lv={lam*v:.0f}",
        ))
    return rows


def fig5_speed():
    cfg, model, dev, ev = cifar_federation()
    rows = []
    for v in (2.0, 8.0, 32.0):
        res, wall = run_policy(
            cfg, model, dev, ev, "afl-spar", ROUNDS,
            speed=v, contact_const=40.0, intercontact_const=300.0,
        )
        rows.append(csv_row(
            f"fig5_speed_v{v:g}", wall / ROUNDS * 1e6,
            f"acc={res.final_eval:.4f};uploads={res.history['uploads'][-1]:.0f}",
        ))
    return rows


def run():
    return fig2_contact() + fig3_intercontact() + fig4_waypoint() + fig5_speed()
