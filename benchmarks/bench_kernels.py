"""Kernel micro-benchmarks: fused sparsify_ef vs 3-pass jnp reference, and
flash-decode vs naive decode attention (CPU wall times are indicative; the
HBM-traffic argument is in the kernel docstrings; TPU is the target)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels.ref import decode_attn_ref, sparsify_ef_ref


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, 6_568_650), jnp.float32)  # ResNet-9 size
    t = jnp.float32(0.5)
    ref_us = _time(jax.jit(sparsify_ef_ref), x, t)
    rows.append(csv_row("sparsify_ef_ref_6.5M", ref_us, "impl=jnp_3pass"))

    q = jnp.asarray(rng.normal(0, 1, (4, 8, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (4, 8192, 2, 128)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (4, 8192, 2, 128)), jnp.bfloat16)
    us = _time(jax.jit(lambda *a: decode_attn_ref(*a, 8192)), q, k, v)
    rows.append(csv_row("decode_attn_ref_8k", us, "impl=jnp"))

    from repro.models.mamba2 import ssd_chunked
    from repro.kernels.ref import ssd_scan_ref

    xx = jnp.asarray(rng.normal(0, 1, (2, 512, 8, 64)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(0, 0.5, (2, 512, 8))), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (2, 512, 64)), jnp.float32)
    cc = jnp.asarray(rng.normal(0, 1, (2, 512, 64)), jnp.float32)
    us_chunk = _time(jax.jit(lambda *args: ssd_chunked(*args, 128)), xx, a, bb, cc)
    us_seq = _time(jax.jit(ssd_scan_ref), xx, a, bb, cc)
    rows.append(csv_row("ssd_chunked_512", us_chunk, f"seq_ref_us={us_seq:.0f}"))
    return rows
