"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see each bench module's docstring
for the figure mapping).  Select subsets with
``python -m benchmarks.run --only mobility,mads``.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("theory", "benchmarks.bench_theory"),
    ("kernels", "benchmarks.bench_kernels"),
    ("compression", "benchmarks.bench_compression"),
    ("mobility", "benchmarks.bench_mobility"),
    ("afl", "benchmarks.bench_afl"),
    ("mads", "benchmarks.bench_mads"),
    ("trajectory", "benchmarks.bench_trajectory"),
    ("ablation", "benchmarks.bench_ablation"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset of: "
                    + ",".join(n for n, _ in MODULES))
    args = ap.parse_args()
    only = {s for s in args.only.split(",") if s}

    import importlib

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, modname in MODULES:
        if only and name not in only:
            continue
        mod = importlib.import_module(modname)
        try:
            for row in mod.run():
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
    print(f"# total_wall_s={time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
