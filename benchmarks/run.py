"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see each bench module's docstring
for the figure mapping).  Select subsets with
``python -m benchmarks.run --only mobility,mads``.

Observability (repro/telemetry):

* ``--out-dir DIR`` — export each suite's rows as ``DIR/BENCH_<suite>.json``
  trajectory files (previous exports of the same suite are carried in a
  bounded ``history`` list); feed two of them to ``tools/bench_compare.py``
  to gate regressions.
* ``--profile-dir DIR`` — wrap each suite in a ``jax.profiler`` trace and
  per-suite wall-clock spans (printed as a phase table at the end).
* ``--smoke`` — reduced iteration counts for suites that support it (CI).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

MODULES = [
    ("theory", "benchmarks.bench_theory"),
    ("kernels", "benchmarks.bench_kernels"),
    ("compression", "benchmarks.bench_compression"),
    ("mobility", "benchmarks.bench_mobility"),
    ("serve", "benchmarks.bench_serve"),
    ("afl", "benchmarks.bench_afl"),
    ("mads", "benchmarks.bench_mads"),
    ("trajectory", "benchmarks.bench_trajectory"),
    ("ablation", "benchmarks.bench_ablation"),
    ("roofline", "benchmarks.bench_roofline"),
]


def _call_run(mod, smoke: bool):
    """Invoke ``mod.run()``, forwarding ``smoke=`` when the suite accepts it."""
    if smoke and "smoke" in inspect.signature(mod.run).parameters:
        return mod.run(smoke=True)
    return mod.run()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset of: "
                    + ",".join(n for n, _ in MODULES))
    ap.add_argument("--out-dir", default="",
                    help="export BENCH_<suite>.json per suite here")
    ap.add_argument("--profile-dir", default="",
                    help="jax.profiler trace output dir (also enables "
                         "TraceAnnotation spans)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iteration counts (suites that support it)")
    args = ap.parse_args()
    only = {s for s in args.only.split(",") if s}

    import importlib

    sys.path.insert(0, "src")  # python -m benchmarks.run without PYTHONPATH
    from repro.telemetry import PhaseTracer, export_bench
    from repro.utils import get_logger

    log = get_logger("repro.bench")
    tracer = PhaseTracer(profile_dir=args.profile_dir or None)
    if args.profile_dir:
        tracer.start()

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, modname in MODULES:
        if only and name not in only:
            continue
        mod = importlib.import_module(modname)
        rows = []
        try:
            with tracer.span(name):
                rows = list(_call_run(mod, args.smoke))
            for row in rows:
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            log.error("suite %s failed: %s: %s", name, type(e).__name__, e)
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
        if args.out_dir and rows:
            path = export_bench(name, rows, out_dir=args.out_dir,
                                meta={"smoke": bool(args.smoke)})
            log.info("wrote %s", path)

    if args.profile_dir:
        tracer.stop()
    if tracer.spans:
        log.info("suite wall clock:\n%s", tracer.summary())
    log.info("total_wall_s=%.1f", time.time() - t0)


if __name__ == "__main__":
    main()
