"""Experiment-engine throughput: loop runner vs compiled scan vs seed-vmap.

rounds/sec of the same AFL experiment (LaneGCN-lite trajectory federation,
exponential scenario, eval every 5 rounds — the paper's convergence-curve
cadence) through the three execution paths at N=20 and N=100 devices:

* ``afl_loop_nX``  — ``core/runner.run_afl``: one jitted round per Python
  iteration (host batch sampling, per-round dispatch, blocking metric
  syncs, eager eval).
* ``afl_scan_nX``  — ``experiments.run_afl_scanned``: the whole run as one
  compiled ``lax.scan`` program (steady-state, post-compile).
* ``afl_scan_telem_nX`` — the scan path with the built-in telemetry
  registry (``repro.telemetry.AFL_REGISTRY``) threaded through the carry;
  its ``overhead_vs_scan`` derived metric is the instrumentation cost.
* ``afl_scan_het_nX`` — the scan path with the heterogeneity layer
  (``scenarios/heterogeneity``) gating the schedule; its
  ``overhead_vs_scan`` shows the gating is a host-side rewrite, not
  per-round compiled work.
* ``afl_scan_jaxscen_nX`` — the scan path fed by the device-resident
  scenario engine (``scenarios/jax_kinematics``, gauss_markov).
* ``afl_vmapSX_nX`` — ``experiments.run_seed_batch``: 8 seeds vmapped into
  one program; rounds/sec counts all seeds' rounds.

The engine's advantage is the per-round host overhead it removes, so the
bench uses the smallest paper-relevant model (trajectory prediction, §VI
Figs. 10-11): with conv-heavy CIFAR federations the CPU grad computation
swamps everything and hides the engine effects (and XLA CPU loses conv
thread-parallelism inside while-loops).  ``derived`` records rounds/sec
and the speedup over the loop path; on parallel hardware, where the
per-round device compute shrinks while host overhead does not, the scan
and vmap speedups grow well beyond the CPU-measured figures.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import csv_row
from repro.configs import FLConfig, get_config
from repro.core.runner import run_afl
from repro.data import DeviceLoader, SyntheticTrajectories
from repro.experiments import DataShard, run_afl_scanned, run_seed_batch
from repro.models.registry import build_model
from repro.telemetry import AFL_REGISTRY

EVAL_EVERY = 5
N_SEEDS = 8


def _federation(n_devices: int, rounds: int, seed: int = 11):
    import numpy as np

    cfg = get_config("lanegcn-argoverse").replace(d_model=4, d_ff=8)
    model = build_model(cfg)
    ds = SyntheticTrajectories(seed=seed)
    data = ds.make_split(40 * n_devices, seed=seed + 1)
    order = np.random.default_rng(seed).permutation(40 * n_devices)
    chunks = np.array_split(order, n_devices)
    dev = [{k: v[c] for k, v in data.items()} for c in chunks]
    ev = ds.make_split(128, seed=seed + 2)
    fl = FLConfig(
        num_devices=n_devices, rounds=rounds, batch_size=2,
        learning_rate=0.05, mean_contact=6.0, mean_intercontact=30.0,
        energy_budget=(40.0, 80.0), sparsifier="sampled", sample_size=256,
    )
    return cfg, model, fl, dev, ev


def _bench(n_devices: int, rounds: int):
    cfg, model, fl, dev, ev = _federation(n_devices, rounds)
    shard = DataShard(dev, fl.batch_size, seed=0)
    rows = []

    # loop runner (warm: afl_round compiles on the first call, time the 2nd)
    run_afl(model, cfg, fl, "mads", shard, ev, rounds=2,
            eval_every=EVAL_EVERY)
    loader = DeviceLoader(dev, fl.batch_size, seed=0)
    t0 = time.time()
    run_afl(model, cfg, fl, "mads", loader, ev, rounds=rounds,
            eval_every=EVAL_EVERY)
    loop_wall = time.time() - t0
    loop_rps = rounds / loop_wall
    rows.append(csv_row(f"afl_loop_n{n_devices}",
                        loop_wall / rounds * 1e6,
                        f"rounds_per_s={loop_rps:.1f}"))

    # scanned engine (steady state: first call compiles, second is timed)
    run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY)
    t0 = time.time()
    run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY, seed=1)
    scan_wall = time.time() - t0
    rows.append(csv_row(
        f"afl_scan_n{n_devices}", scan_wall / rounds * 1e6,
        f"rounds_per_s={rounds / scan_wall:.1f}"
        f";speedup_vs_loop={loop_wall / scan_wall:.1f}x"))

    # scanned engine with the built-in metric registry threaded through the
    # scan carry — the telemetry overhead row (acceptance gate: within 5%
    # of the plain scan; histograms accumulate on device, fetched once)
    run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY, telemetry=AFL_REGISTRY)
    t0 = time.time()
    run_afl_scanned(model, cfg, fl, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY, seed=1, telemetry=AFL_REGISTRY)
    telem_wall = time.time() - t0
    rows.append(csv_row(
        f"afl_scan_telem_n{n_devices}", telem_wall / rounds * 1e6,
        f"rounds_per_s={rounds / telem_wall:.1f}"
        f";overhead_vs_scan={telem_wall / scan_wall:.2f}x"))

    # heterogeneity layer (availability/dropout gating): a host-side
    # schedule rewrite riding the SAME compiled scan — the overhead row
    # shows the layer costs re-tracing once, not per-round work
    fl_het = dataclasses.replace(fl, het_dropout=0.1, het_availability=0.9)
    run_afl_scanned(model, cfg, fl_het, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY)
    t0 = time.time()
    run_afl_scanned(model, cfg, fl_het, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY, seed=1)
    het_wall = time.time() - t0
    rows.append(csv_row(
        f"afl_scan_het_n{n_devices}", het_wall / rounds * 1e6,
        f"rounds_per_s={rounds / het_wall:.1f}"
        f";overhead_vs_scan={het_wall / scan_wall:.2f}x"))

    # device-resident scenario generation feeding the scan engine
    # (scenarios/jax_kinematics: trace -> schedule without host round-trips)
    fl_jax = dataclasses.replace(fl, mobility_model="gauss_markov",
                                 speed=10.0, scenario_backend="jax")
    run_afl_scanned(model, cfg, fl_jax, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY)
    t0 = time.time()
    run_afl_scanned(model, cfg, fl_jax, "mads", shard, ev, rounds=rounds,
                    eval_every=EVAL_EVERY, seed=1)
    jaxscen_wall = time.time() - t0
    rows.append(csv_row(
        f"afl_scan_jaxscen_n{n_devices}", jaxscen_wall / rounds * 1e6,
        f"rounds_per_s={rounds / jaxscen_wall:.1f}"))

    # seed-vmapped batch (8 runs in one program; count every seed's rounds)
    seeds = tuple(range(N_SEEDS))
    run_seed_batch(model, cfg, fl, "mads", shard, ev, seeds=seeds,
                   rounds=rounds, eval_every=EVAL_EVERY)
    t0 = time.time()
    run_seed_batch(model, cfg, fl, "mads", shard, ev,
                   seeds=[s + 100 for s in seeds], rounds=rounds,
                   eval_every=EVAL_EVERY)
    vmap_wall = time.time() - t0
    total = rounds * N_SEEDS
    rows.append(csv_row(
        f"afl_vmap{N_SEEDS}_n{n_devices}", vmap_wall / total * 1e6,
        f"rounds_per_s={total / vmap_wall:.1f}"
        f";speedup_vs_loop={total / vmap_wall / loop_rps:.1f}x"))
    return rows


def run(smoke: bool = False):
    if smoke:
        return _bench(8, 12)
    return _bench(20, 60) + _bench(100, 30)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row)
